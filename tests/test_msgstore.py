"""The disk message tier (tentpole): OMS run store + §3.3.1 external merge,
combiner-less streamed execution bit-matching mode="basic", the run-file
message log, and single-shard recovery for streamed jobs."""

import os

import numpy as np
import pytest

from repro.core import (
    ChannelConfig, DistinctInLabels, EngineConfig, GraphDEngine,
    MessageSpillConfig, PageRank, SecondMinLabel, StreamConfig,
)
from repro.core.checkpoint import (
    Checkpointer, RunFileMessageLog, recover_shard_streamed,
)
from repro.graph import partition_graph, partition_graph_streamed, rmat_graph
from repro.streams import MessageRunStore


@pytest.fixture
def spilled(tmp_path):
    g = rmat_graph(scale=7, edge_factor=6, seed=9)
    pg_full, rmap = partition_graph(g, n_shards=4, edge_block=32)
    pg, _, store = partition_graph_streamed(
        g, 4, str(tmp_path / "spill"), edge_block=32, recode=rmap
    )
    return g, pg_full, pg, rmap, store


def _random_runs(rng, n_runs, P, max_len):
    runs = []
    for _ in range(n_runs):
        m = int(rng.integers(1, max_len))
        dp = np.sort(rng.integers(0, P, size=m)).astype(np.int32)
        msg = rng.integers(0, 1000, size=m).astype(np.int32)
        runs.append((dp, msg))
    return runs


# ---------------------------------------------------------------------------
# the run store: sorted-run append, k-way merge, compaction, persistence
# ---------------------------------------------------------------------------

class TestMessageRunStore:
    P = 97

    def _store(self, tmp_path, **kw):
        return MessageRunStore(str(tmp_path / "oms"), 2, self.P, np.int32,
                               **kw)

    def test_merge_matches_global_sort(self, tmp_path):
        """Runs much longer than the cursor window: the k-way merge must
        equal one global sort of all spilled messages."""
        rng = np.random.default_rng(0)
        store = self._store(tmp_path)
        runs = _random_runs(rng, n_runs=7, P=self.P, max_len=300)
        for dp, msg in runs:
            store.append_run(0, dp, msg, tag=0)
        got_dp, got_msg = [], []
        for dp, msg in store.iter_merged(0, read_chunk=16):
            got_dp.append(dp)
            got_msg.append(msg)
        got_dp = np.concatenate(got_dp)
        got_msg = np.concatenate(got_msg)
        all_dp = np.concatenate([r[0] for r in runs])
        all_msg = np.concatenate([r[1] for r in runs])
        assert (np.diff(got_dp) >= 0).all()
        # same multiset of (dst, payload) pairs as a global sort
        want = np.lexsort((all_msg, all_dp))
        got = np.lexsort((got_msg, got_dp))
        assert np.array_equal(got_dp[got], all_dp[want])
        assert np.array_equal(got_msg[got], all_msg[want])

    def test_rejects_unsorted_run(self, tmp_path):
        store = self._store(tmp_path)
        with pytest.raises(ValueError, match="sorted"):
            store.append_run(0, np.array([3, 1], np.int32),
                            np.array([0, 0], np.int32))

    def test_merged_slices_destination_aligned(self, tmp_path):
        rng = np.random.default_rng(1)
        store = self._store(tmp_path)
        for dp, msg in _random_runs(rng, n_runs=5, P=self.P, max_len=120):
            store.append_run(0, dp, msg)
        counts = store.dest_counts(0).copy()
        cap = max(32, int(counts.max()))
        seen = np.zeros(self.P, np.int64)
        covered_union = np.zeros(self.P, bool)
        for sdp, smsg, covered in store.merged_slices(0, cap, read_chunk=16):
            assert sdp.shape == (cap,) and smsg.shape == (cap,)
            real = sdp < self.P
            # padding carries the P sentinel
            assert (sdp[~real] == self.P).all()
            # every covered destination's run is ENTIRELY in this slice
            in_slice = np.bincount(sdp[real], minlength=self.P)
            assert np.array_equal(in_slice[covered], counts[covered])
            assert not covered_union[covered].any()  # disjoint coverage
            covered_union |= covered
            seen += in_slice
        assert np.array_equal(seen, counts)
        assert np.array_equal(covered_union, counts > 0)

    def test_slice_capacity_guard(self, tmp_path):
        store = self._store(tmp_path)
        dp = np.zeros(40, np.int32)  # one destination, 40 messages
        store.append_run(0, dp, np.arange(40, dtype=np.int32))
        with pytest.raises(ValueError, match="capacity"):
            list(store.merged_slices(0, 16))

    def test_compact_tag_bounds_fanin(self, tmp_path):
        """Many same-tag runs collapse to ONE (multi-pass, fan-in 2), and the
        merged stream is unchanged."""
        rng = np.random.default_rng(2)
        store = self._store(tmp_path)
        runs = _random_runs(rng, n_runs=9, P=self.P, max_len=50)
        for dp, msg in runs:
            store.append_run(0, dp, msg, tag=3)
        before = [np.concatenate(x) for x in zip(
            *store.iter_merged(0, read_chunk=8))]
        store.compact_tag(0, 3, fanin=2, read_chunk=8)
        assert len(store.runs(0)) == 1
        after = [np.concatenate(x) for x in zip(
            *store.iter_merged(0, read_chunk=8))]
        assert np.array_equal(before[0], after[0])
        order_b = np.lexsort((before[1], before[0]))
        order_a = np.lexsort((after[1], after[0]))
        assert np.array_equal(before[1][order_b], after[1][order_a])

    def test_index_roundtrip_and_counts_rebuild(self, tmp_path):
        rng = np.random.default_rng(3)
        store = self._store(tmp_path)
        for j, (dp, msg) in enumerate(
                _random_runs(rng, n_runs=4, P=self.P, max_len=60)):
            store.append_run(j % 2, dp, msg, tag=j)
        store.save_index()
        store.close()
        re = MessageRunStore.open(store.dir)
        for k in range(2):
            assert re.runs(k) == store.runs(k)
            assert np.array_equal(re.dest_counts(k), store.dest_counts(k))

    def test_counts_rebuild_ignores_dead_regions(self, tmp_path):
        """Regression: compaction leaves superseded segments in the files;
        a reopened store must rebuild counts from the LIVE runs only (or the
        merge planner would expect phantom messages and die mid-stream)."""
        rng = np.random.default_rng(4)
        store = self._store(tmp_path)
        for dp, msg in _random_runs(rng, n_runs=5, P=self.P, max_len=40):
            store.append_run(0, dp, msg, tag=1)
        store.compact_tag(0, 1, fanin=2, read_chunk=8)
        want = store.dest_counts(0).copy()
        store.save_index()
        store.close()
        re = MessageRunStore.open(store.dir)
        assert np.array_equal(re.dest_counts(0), want)
        merged = np.concatenate(
            [dp for dp, _ in re.iter_merged(0, read_chunk=8)]
        )
        assert merged.size == want.sum()  # merge plan == live messages

    def test_counts_rebuild_uses_cnt_channel(self, tmp_path):
        store = self._store(tmp_path, with_counts=True)
        dp = np.array([2, 5], np.int32)
        store.append_run(0, dp, np.array([7, 9], np.int32),
                         cnt=np.array([3, 4], np.int32), tag=0)
        store.save_index()
        store.close()
        re = MessageRunStore.open(store.dir)
        assert re.dest_counts(0)[2] == 3 and re.dest_counts(0)[5] == 4

    def test_compact_preserves_cnt_channel(self, tmp_path):
        """Regression: compaction must rewrite ALL channels — dropping cnt
        left _sizes pointing past the cnt file's extent (memmap error)."""
        store = self._store(tmp_path, with_counts=True)
        for j in range(3):
            dp = np.array([j, j + 10], np.int32)
            store.append_run(0, dp, dp * 2,
                             cnt=np.array([j + 1, j + 2], np.int32), tag=4)
        store.compact_tag(0, 4, fanin=2, read_chunk=2)
        assert len(store.runs(0)) == 1
        dp, msg, cnt = store.read_run(0, store.runs(0)[0])
        assert (np.diff(dp) >= 0).all() and dp.size == 6
        # (dp, msg, cnt) triples survive compaction intact
        triples = sorted(zip(dp.tolist(), msg.tolist(), cnt.tolist()))
        want = sorted(
            (j + 10 * b, (j + 10 * b) * 2, j + 1 + b)
            for j in range(3) for b in (0, 1)
        )
        assert triples == want

    def test_rejects_degenerate_slice_cap(self, tmp_path, spilled):
        _, _, pg, _, store = spilled
        with pytest.raises(ValueError, match="slice_cap"):
            GraphDEngine(
                pg,
                DistinctInLabels(),
                config=EngineConfig(mode="streamed", spill=MessageSpillConfig(slice_cap=0)),
                stream_store=store,
            )

    def test_clear_dest_frees_disk(self, tmp_path):
        store = self._store(tmp_path)
        dp = np.arange(10, dtype=np.int32)
        store.append_run(0, dp, dp)
        assert store.disk_bytes() > 0
        store.clear_dest(0)
        assert store.disk_bytes() == 0
        assert store.n_messages(0) == 0 and store.runs(0) == []


# ---------------------------------------------------------------------------
# combiner-less streamed execution: bit-match mode="basic" (§3.3 OMS claim)
# ---------------------------------------------------------------------------

class TestStreamedNoCombiner:
    def _pair(self, spilled, prog_factory, slice_cap=4096, read_chunk=4096):
        _, pg_full, pg, _, store = spilled
        eb = GraphDEngine(
                 pg_full,
                 prog_factory(),
                 config=EngineConfig(mode="basic"),
             )
        (vb, _), hb = eb.run()
        es = GraphDEngine(
                 pg,
                 prog_factory(),
                 config=EngineConfig(
                     mode="streamed",
                     stream=StreamConfig(chunk_blocks=2),
                     spill=MessageSpillConfig(slice_cap=slice_cap,
                                              read_chunk=read_chunk),
                 ),
                 stream_store=store,
             )
        (vs, _), hs = es.run()
        return eb.gather_values(vb), es.gather_values(vs), hb, hs

    def test_distinct_labels_multistep_bitmatch(self, spilled):
        got_b, got_s, hb, hs = self._pair(
            spilled, lambda: DistinctInLabels(n_groups=5, rounds=3),
            slice_cap=256, read_chunk=64,
        )
        assert got_b == got_s  # integer values: bit-for-bit
        assert [h.n_msgs for h in hb] == [h.n_msgs for h in hs]
        assert [h.n_active for h in hb] == [h.n_active for h in hs]

    def test_second_min_label_bitmatch(self, spilled):
        got_b, got_s, _, _ = self._pair(
            spilled, SecondMinLabel, slice_cap=128, read_chunk=32,
        )
        assert got_b == got_s

    def test_tiny_slices_force_many_apply_calls(self, spilled):
        """Slice capacity just above the max in-degree: the merged stream is
        consumed through MANY destination-aligned slices and results must
        still be exact."""
        g, pg_full, pg, _, store = spilled
        prog = lambda: DistinctInLabels(n_groups=5)
        eb = GraphDEngine(pg_full, prog(), config=EngineConfig(mode="basic"))
        (vb, _), _ = eb.run()
        es = GraphDEngine(
                 pg,
                 prog(),
                 config=EngineConfig(mode="streamed", spill=MessageSpillConfig(slice_cap=1, read_chunk=8, merge_fanin=2)),
                 stream_store=store,
             )
        (vs, _), _ = es.run()
        assert eb.gather_values(vb) == es.gather_values(vs)
        # the cap auto-bumped (in powers of two) to the max in-degree —
        # Pregel's own lower bound (compute() holds one vertex's list) —
        # and no further
        max_in = int(np.unique(np.asarray(g.dst), return_counts=True)[1].max())
        assert es._msg_slice_cap_eff < 2 * max_in

    def test_spill_dir_cleaned_after_run(self, spilled):
        _, _, pg, _, store = spilled
        es = GraphDEngine(
                 pg,
                 DistinctInLabels(n_groups=5, rounds=2),
                 config=EngineConfig(mode="streamed"),
                 stream_store=store,
             )
        es.run()
        spill = es.msg_spill_dir
        assert (not os.path.exists(spill)) or os.listdir(spill) == []

    def test_resident_independent_of_E(self, tmp_path):
        """The acceptance bound: combiner-less streamed RAM (vertex arrays +
        staging + merge windows + one apply slice) is a constant of the
        config, not of |E|."""
        def engine(edge_factor, tag):
            g = rmat_graph(scale=8, edge_factor=edge_factor, seed=7)
            pg, _, store = partition_graph_streamed(
                g, 4, str(tmp_path / f"sp{tag}"), edge_block=32
            )
            return g, GraphDEngine(
                          pg,
                          DistinctInLabels(n_groups=8),
                          config=EngineConfig(mode="streamed", stream=StreamConfig(chunk_blocks=2), spill=MessageSpillConfig(slice_cap=8192)),
                          stream_store=store,
                      )

        g1, e1 = engine(4, "a")
        g2, e2 = engine(48, "b")
        assert g2.n_edges > 4 * g1.n_edges and g2.n_vertices == g1.n_vertices
        e1.run()
        e2.run()
        ram = lambda m: (m["resident"] + m["buffers"] + m["staging"]
                         + m["msg_staging"])
        m1, m2 = e1.memory_model(), e2.memory_model()
        assert ram(m1) == ram(m2)  # flat despite >4x the edges
        assert m2["streamed"] > m1["streamed"]  # ... while disk grows


# ---------------------------------------------------------------------------
# run-file message log: engine-driven GC + single-shard streamed recovery
# ---------------------------------------------------------------------------

class TestRunFileMessageLog:
    def test_kill_and_recover_combiner(self, tmp_path):
        g = rmat_graph(scale=7, edge_factor=8, seed=3)
        pg, _, store = partition_graph_streamed(
            g, 4, str(tmp_path / "s"), edge_block=64
        )
        prog = lambda: PageRank(supersteps=8)
        (v_ref, a_ref), _ = GraphDEngine(
                                pg,
                                prog(),
                                config=EngineConfig(mode="streamed"),
                                stream_store=store,
                            ).run()
        ck = Checkpointer(str(tmp_path / "ck"), every=3)
        ml = RunFileMessageLog(str(tmp_path / "logs"))
        eng = GraphDEngine(
                  pg,
                  prog(),
                  config=EngineConfig(mode="streamed"),
                  stream_store=store,
                  message_log=ml,
              )
        ck.save(0, *eng.init())
        eng.run(checkpointer=ck)  # then "kill" shard 2
        vj, aj = recover_shard_streamed(
            pg, prog(), failed=2, ckpt=ck, log=ml, store=store,
            target_step=8,
        )
        assert np.abs(np.asarray(vj) - np.asarray(v_ref)[2]).max() < 1e-6
        assert np.array_equal(np.asarray(aj), np.asarray(a_ref)[2])

    def test_kill_and_recover_combinerless(self, tmp_path):
        g = rmat_graph(scale=7, edge_factor=6, seed=9)
        pg, _, store = partition_graph_streamed(
            g, 4, str(tmp_path / "s"), edge_block=32
        )
        prog = lambda: DistinctInLabels(n_groups=7, rounds=4)
        (v_ref, _), _ = GraphDEngine(
                            pg,
                            prog(),
                            config=EngineConfig(mode="streamed"),
                            stream_store=store,
                        ).run()
        ck = Checkpointer(str(tmp_path / "ck"), every=2)
        ml = RunFileMessageLog(str(tmp_path / "logs"))
        eng = GraphDEngine(
                  pg,
                  prog(),
                  config=EngineConfig(mode="streamed"),
                  stream_store=store,
                  message_log=ml,
              )
        ck.save(0, *eng.init())
        eng.run(checkpointer=ck)
        vj, _ = recover_shard_streamed(
            pg, prog(), failed=1, ckpt=ck, log=ml, store=store,
            target_step=4,
        )
        assert np.array_equal(np.asarray(vj), np.asarray(v_ref)[1])

    def test_engine_gcs_logs_after_checkpoint(self, tmp_path):
        """Regression (paper §3.4): OMS logs must be dropped as soon as a
        newer checkpoint is durable, in the streamed driver too."""
        g = rmat_graph(scale=7, edge_factor=8, seed=3)
        pg, _, store = partition_graph_streamed(
            g, 4, str(tmp_path / "s"), edge_block=64
        )
        ck = Checkpointer(str(tmp_path / "ck"), every=3)
        ml = RunFileMessageLog(str(tmp_path / "logs"))
        eng = GraphDEngine(
                  pg,
                  PageRank(supersteps=8),
                  config=EngineConfig(mode="streamed"),
                  stream_store=store,
                  message_log=ml,
              )
        eng.run(checkpointer=ck)
        # checkpoints landed at steps 3 and 6 => only logs >= 6 survive
        assert sorted(os.listdir(str(tmp_path / "logs"))) == [
            "step-000006", "step-000007",
        ]

    def test_reopened_step_drops_stale_index(self, tmp_path):
        """Regression: re-executing a crashed superstep truncates the run
        files; the PREVIOUS attempt's index.json must go with them, or a
        later open() maps past the truncated files."""
        ml = RunFileMessageLog(str(tmp_path / "logs"))
        ml.configure(n_shards=2, P=16, msg_dtype=np.float32, e0=0.0,
                     combined=False)
        s1 = ml.open_step(5)
        s1.append_run(0, np.arange(8, dtype=np.int32),
                      np.ones(8, np.float32), tag=1)
        ml.close_step(5)  # crash at step 6, restart, re-run step 5:
        s2 = ml.open_step(5)
        assert not os.path.exists(os.path.join(s2.dir, "index.json"))
        s2.append_run(0, np.arange(2, dtype=np.int32),
                      np.ones(2, np.float32), tag=1)
        ml.close_step(5)  # second crash AFTER publishing; reopen must work
        re = ml._store_for(5)
        assert [seg.length for seg in re.runs(0)] == [2]

    def test_recover_across_empty_superstep(self, tmp_path):
        """Regression: a superstep whose frontier died (empty skip() plan)
        must still publish an (empty) per-step log dir, or recovery of that
        step crashes on a missing index."""
        from repro.core import DegreeSum

        class OneShotSum(DegreeSum):
            num_supersteps = 3  # steps 1..2 run with an all-inactive frontier

        g = rmat_graph(scale=6, edge_factor=4, seed=2)
        pg, _, store = partition_graph_streamed(
            g, 2, str(tmp_path / "s"), edge_block=32
        )
        (v_ref, _), _ = GraphDEngine(
                            pg,
                            OneShotSum(),
                            config=EngineConfig(mode="streamed"),
                            stream_store=store,
                        ).run()
        ck = Checkpointer(str(tmp_path / "ck"), every=10)  # never fires
        ml = RunFileMessageLog(str(tmp_path / "logs"))
        eng = GraphDEngine(
                  pg,
                  OneShotSum(),
                  config=EngineConfig(mode="streamed"),
                  stream_store=store,
                  message_log=ml,
              )
        ck.save(0, *eng.init())
        eng.run(checkpointer=ck)
        vj, _ = recover_shard_streamed(
            pg, OneShotSum(), failed=0, ckpt=ck, log=ml, store=store,
            target_step=3,
        )
        assert np.array_equal(np.asarray(vj), np.asarray(v_ref)[0])

    def test_dense_reads_rejected_on_raw_log(self, tmp_path):
        """load_for_dest (the combined-A_s recovery read) must fail loudly,
        not with a tuple-unpack error, on a raw combiner-less log."""
        ml = RunFileMessageLog(str(tmp_path / "logs"))
        ml.configure(n_shards=2, P=16, msg_dtype=np.int32, e0=0,
                     combined=False)
        st = ml.open_step(0)
        st.append_run(1, np.arange(4, dtype=np.int32),
                      np.arange(4, dtype=np.int32), tag=0)
        ml.close_step(0)
        with pytest.raises(ValueError, match="recover_shard_streamed"):
            ml.load_for_dest(0, 1, 2, skip_shard=1)

    def test_runfile_log_with_min_combiner_in_memory_driver(self, tmp_path):
        """Regression: the run-file log densifies sparse runs with the
        combiner identity e0. Used with the IN-MEMORY logged driver and a
        MIN combiner (SSSP: e0=inf), a wrong default identity (0) poisons
        every position some source shard never messaged."""
        from repro.core import SSSP
        from repro.core.checkpoint import recover_shard

        g = rmat_graph(scale=7, edge_factor=6, seed=5, weights="uniform")
        pg, rmap = partition_graph(g, n_shards=4, edge_block=64)
        src_new = int(rmap.to_new(np.array([int(g.vertex_ids[0])]))[0])
        prog = lambda: SSSP(src_new)
        (v_ref, _), hist = GraphDEngine(pg, prog()).run()
        ck = Checkpointer(str(tmp_path / "ck"), every=3)
        ml = RunFileMessageLog(str(tmp_path / "logs"))
        eng = GraphDEngine(pg, prog(), message_log=ml)
        ck.save(0, *eng.init())
        eng.run(checkpointer=ck)
        vj, _ = recover_shard(pg, prog(), failed=1, ckpt=ck, log=ml,
                              target_step=len(hist))
        vj, vr = np.asarray(vj), np.asarray(v_ref)[1]
        assert ((vj == vr) | (np.isinf(vj) & np.isinf(vr))).all()

    def test_log_survives_until_next_checkpoint(self, tmp_path):
        """No checkpointer => nothing is ever GC'd (the engine may not drop
        OMSs it might still need for recovery)."""
        g = rmat_graph(scale=6, edge_factor=4, seed=2)
        pg, _, store = partition_graph_streamed(
            g, 2, str(tmp_path / "s"), edge_block=32
        )
        ml = RunFileMessageLog(str(tmp_path / "logs"))
        eng = GraphDEngine(
                  pg,
                  PageRank(supersteps=3),
                  config=EngineConfig(mode="streamed"),
                  stream_store=store,
                  message_log=ml,
              )
        eng.run()
        assert sorted(os.listdir(str(tmp_path / "logs"))) == [
            f"step-{s:06d}" for s in range(3)
        ]


# ---------------------------------------------------------------------------
# dead-region reclamation (ISSUE 3 satellite): compaction must not leak disk
# until the per-step store is deleted
# ---------------------------------------------------------------------------

class TestDeadRegionReclamation:
    P = 97

    def _fill(self, store, rng, dest=0, tag=0, n_runs=6, max_len=300):
        for dp, msg in _random_runs(rng, n_runs, self.P, max_len):
            store.append_run(dest, dp, msg, tag=tag)

    @pytest.mark.parametrize("compress", [False, True])
    def test_disk_shrinks_after_compaction(self, tmp_path, compress):
        """Regression: compact_tag used to append the merged run and leave
        the superseded segments as dead regions forever (ROADMAP item).
        Now the vacuum reclaims them: post-compaction disk is the live
        bytes, not live + a full dead copy."""
        rng = np.random.default_rng(1)
        store = MessageRunStore(str(tmp_path / "oms"), 2, self.P, np.int32,
                                compress=compress)
        self._fill(store, rng)
        before = store.disk_bytes()
        ref = [np.concatenate(ch) for ch in
               zip(*store.iter_merged(0, read_chunk=32))]
        store.compact_tag(0, 0, fanin=4, read_chunk=32)
        # without reclamation this would be ~2x `before`
        assert store.disk_bytes() <= before * 1.05
        assert store.dead_bytes(0) == 0
        got = [np.concatenate(ch) for ch in
               zip(*store.iter_merged(0, read_chunk=32))]
        # same destination-sorted stream; equal-dp tie order may legally
        # differ after compaction (apply_list is vertex-order-insensitive)
        assert np.array_equal(ref[0], got[0])
        assert np.array_equal(ref[1][np.lexsort((ref[1], ref[0]))],
                              got[1][np.lexsort((got[1], got[0]))])

    def test_vacuum_rebases_offsets_and_preserves_other_tags(self, tmp_path):
        rng = np.random.default_rng(2)
        store = MessageRunStore(str(tmp_path / "oms"), 2, self.P, np.int32)
        self._fill(store, rng, tag=0, n_runs=5)
        self._fill(store, rng, tag=1, n_runs=2)
        ref = [np.concatenate(ch) for ch in
               zip(*store.iter_merged(0, read_chunk=16))]
        store.compact_tag(0, 0, fanin=2, read_chunk=16)
        got = [np.concatenate(ch) for ch in
               zip(*store.iter_merged(0, read_chunk=16))]
        assert np.array_equal(ref[0], got[0])
        assert np.array_equal(ref[1][np.lexsort((ref[1], ref[0]))],
                              got[1][np.lexsort((got[1], got[0]))])
        # run table is dense again: offsets start at 0 and chain contiguously
        runs = sorted(store.runs(0), key=lambda s: s.offset)
        assert runs[0].offset == 0
        for a, b in zip(runs, runs[1:]):
            assert b.offset == a.offset + a.length

    def test_vacuumed_store_reopens(self, tmp_path):
        rng = np.random.default_rng(3)
        store = MessageRunStore(str(tmp_path / "oms"), 2, self.P, np.int32)
        self._fill(store, rng)
        store.compact_tag(0, 0, fanin=3, read_chunk=16)
        ref = [np.concatenate(ch) for ch in
               zip(*store.iter_merged(0, read_chunk=16))]
        store.save_index()
        store.close()
        re = MessageRunStore.open(str(tmp_path / "oms"))
        got = [np.concatenate(ch) for ch in
               zip(*re.iter_merged(0, read_chunk=16))]
        assert np.array_equal(ref[0], got[0])
        assert np.array_equal(ref[1], got[1])
        assert np.array_equal(re.dest_counts(0), store.dest_counts(0))

    def test_engine_step_disk_bounded_by_live(self, spilled, tmp_path):
        """End to end: a combiner-less streamed superstep's OMS store must
        never hold more than ~2x its live bytes even though every source
        switch compacts (the paper's multi-pass merge)."""
        _, _, pg, _, store = spilled
        from repro.core.checkpoint import RunFileMessageLog

        log = RunFileMessageLog(str(tmp_path / "log"))
        eng = GraphDEngine(
                  pg,
                  DistinctInLabels(n_groups=8, rounds=1),
                  config=EngineConfig(mode="streamed", spill=MessageSpillConfig(merge_fanin=2, read_chunk=64)),
                  stream_store=store,
                  message_log=log,
              )
        eng.run()
        mstore = log._store_for(0)
        for k in range(pg.n_shards):
            live = mstore.live_bytes(k)
            assert mstore.dead_bytes(k) <= max(live, 1)


# ---------------------------------------------------------------------------
# compressed message runs (the compress= knob)
# ---------------------------------------------------------------------------

class TestCompressedRuns:
    def test_compressed_streamed_run_bitmatches(self, spilled, tmp_path):
        _, pg_full, pg, _, store = spilled
        prog = lambda: DistinctInLabels(n_groups=8, rounds=2)
        (v_ref, _), _ = GraphDEngine(
                            pg_full,
                            prog(),
                            config=EngineConfig(mode="basic"),
                        ).run()
        eng = GraphDEngine(
                  pg,
                  prog(),
                  config=EngineConfig(mode="streamed", channel=ChannelConfig(compress=True)),
                  stream_store=store,
              )
        (v, _), _ = eng.run()
        assert np.array_equal(np.asarray(v), np.asarray(v_ref))

    def test_compressed_log_recovers_and_is_smaller(self, tmp_path):
        g = rmat_graph(scale=7, edge_factor=6, seed=9)
        pg, _, store = partition_graph_streamed(
            g, 4, str(tmp_path / "sp"), edge_block=32
        )
        sizes = {}
        for compress in (False, True):
            tag = "c" if compress else "p"
            ck = Checkpointer(str(tmp_path / f"ck-{tag}"), every=10)
            log = RunFileMessageLog(str(tmp_path / f"log-{tag}"))
            eng = GraphDEngine(
                      pg,
                      DistinctInLabels(n_groups=8, rounds=2),
                      config=EngineConfig(mode="streamed", channel=ChannelConfig(compress=compress)),
                      stream_store=store,
                      message_log=log,
                  )
            ck.save(0, *eng.init())
            (v_ref, a_ref), _ = eng.run(checkpointer=ck)
            sizes[tag] = sum(
                log._store_for(s).disk_bytes() for s in (0, 1)
            )
            vj, aj = recover_shard_streamed(
                pg, DistinctInLabels(n_groups=8, rounds=2), failed=2,
                ckpt=ck, log=log, store=store, target_step=2,
            )
            assert np.array_equal(np.asarray(vj), np.asarray(v_ref)[2])
            assert np.array_equal(np.asarray(aj), np.asarray(a_ref)[2])
        assert sizes["c"] < sizes["p"]


class TestPayloadCompressedRuns:
    """compress_payload= end to end on the OMS tier (PR 5)."""

    def test_payload_streamed_run_bitmatches(self, spilled, tmp_path):
        _, pg_full, pg, _, store = spilled
        prog = lambda: DistinctInLabels(n_groups=8, rounds=2)
        (v_ref, _), _ = GraphDEngine(
                            pg_full,
                            prog(),
                            config=EngineConfig(mode="basic"),
                        ).run()
        eng = GraphDEngine(
            pg, prog(),
            config=EngineConfig(
                mode="streamed",
                channel=ChannelConfig(compress=True, compress_payload=True),
            ),
            stream_store=store,
        )
        (v, _), _ = eng.run()
        assert np.array_equal(np.asarray(v), np.asarray(v_ref))

    def test_payload_log_recovers_and_is_smaller(self, tmp_path):
        g = rmat_graph(scale=7, edge_factor=6, seed=9)
        pg, _, store = partition_graph_streamed(
            g, 4, str(tmp_path / "sp"), edge_block=32
        )
        sizes = {}
        for compress_payload in (False, True):
            tag = "cp" if compress_payload else "p"
            ck = Checkpointer(str(tmp_path / f"ck-{tag}"), every=10)
            log = RunFileMessageLog(str(tmp_path / f"log-{tag}"))
            eng = GraphDEngine(
                pg, DistinctInLabels(n_groups=8, rounds=2),
                config=EngineConfig(
                    mode="streamed",
                    channel=ChannelConfig(
                        compress_payload=compress_payload),
                ),
                stream_store=store, message_log=log,
            )
            ck.save(0, *eng.init())
            (v_ref, a_ref), _ = eng.run(checkpointer=ck)
            sizes[tag] = sum(
                log._store_for(s).disk_bytes() for s in (0, 1)
            )
            vj, aj = recover_shard_streamed(
                pg, DistinctInLabels(n_groups=8, rounds=2), failed=2,
                ckpt=ck, log=log, store=store, target_step=2,
            )
            assert np.array_equal(np.asarray(vj), np.asarray(v_ref)[2])
            assert np.array_equal(np.asarray(aj), np.asarray(a_ref)[2])
        assert sizes["cp"] < sizes["p"]

    def test_bf16_store_rejects_integer_messages(self, tmp_path):
        with pytest.raises(ValueError):
            MessageRunStore(str(tmp_path / "s"), 2, 16, np.int32,
                            compress_payload="bf16")

    def test_bf16_rejects_message_log(self, tmp_path):
        """bf16 is a lossy WIRE codec; a message log backed by it would
        make recover_shard_streamed (which regenerates the failed shard's
        own groups exactly) diverge from the live run — refused up front."""
        g = rmat_graph(scale=6, edge_factor=4, seed=1)
        pg, _, store = partition_graph_streamed(
            g, 2, str(tmp_path / "sp"), edge_block=32
        )
        with pytest.raises(ValueError, match="lossy wire codec"):
            GraphDEngine(
                pg, PageRank(supersteps=2),
                config=EngineConfig(
                    mode="streamed",
                    channel=ChannelConfig(compress_payload="bf16"),
                ),
                stream_store=store,
                message_log=RunFileMessageLog(str(tmp_path / "logs")),
            )

    def test_payload_vacuum_reclaims_and_preserves(self, tmp_path):
        """Compaction + vacuum over payload-compressed runs must yield the
        EXACT merge stream of an uncompressed store fed identically — the
        codec (and the dead-region rewrite) must be invisible."""
        rng = np.random.default_rng(3)
        st = MessageRunStore(str(tmp_path / "v"), 2, 64, np.float32,
                             compress_payload=True)
        ref = MessageRunStore(str(tmp_path / "ref"), 2, 64, np.float32)
        for _ in range(12):
            dp = np.sort(rng.integers(0, 64, 700)).astype(np.int32)
            msg = rng.random(700, dtype=np.float32)
            st.append_run(1, dp, msg, tag=0)
            ref.append_run(1, dp, msg, tag=0)
        for s in (st, ref):
            s.compact_tag(1, 0, fanin=3, read_chunk=97)
        assert st.dead_bytes(1) < st.live_bytes(1)  # vacuumed en route
        merged = [np.concatenate(x) for x in zip(*st.iter_merged(1, 53))]
        want = [np.concatenate(x) for x in zip(*ref.iter_merged(1, 53))]
        assert np.array_equal(merged[0], want[0])
        assert np.array_equal(merged[1], want[1])
        assert st.disk_bytes() < ref.disk_bytes()
