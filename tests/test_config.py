"""Typed-config surface: field validation, cross-config invariants, and the
one-release deprecation shim that maps every legacy ``GraphDEngine`` kwarg
onto its ``EngineConfig`` field (single DeprecationWarning, hard error on a
conflicting kwarg+config mix)."""

import warnings

import pytest

from repro.core import (
    ConfigError, EngineConfig, GraphDEngine, HashMin, PageRank,
)
from repro.core.config import (
    ChannelConfig, LEGACY_KWARGS, MessageSpillConfig, RecoveryConfig,
    StreamConfig,
)
from repro.graph import partition_graph, partition_graph_streamed, rmat_graph


@pytest.fixture(scope="module")
def small():
    g = rmat_graph(scale=6, edge_factor=6, seed=11)
    pg, rmap = partition_graph(g, n_shards=3, edge_block=32)
    return g, pg


# ---------------------------------------------------------------------------
# the deprecation shim: every legacy kwarg -> its config field
# ---------------------------------------------------------------------------

# non-default probe value per legacy kwarg (+ extra kwargs needed to pass
# cross-config validation, e.g. pipeline= is a streamed-mode knob)
_PROBES = {
    "mode": ("basic", {}),
    "sparse_cap_frac": (0.5, {}),
    "adapt_threshold": (0.25, {}),
    "backend": ("pallas", {}),
    "kernel_windows": (256, {}),
    "stream_chunk_blocks": (3, {}),
    "stream_depth": (4, {}),
    "msg_slice_cap": (99, {}),
    "msg_read_chunk": (77, {}),
    "msg_merge_fanin": (5, {}),
    "msg_spill_dir": ("/tmp/oms-probe", {}),
    "pipeline": (True, {"mode": "streamed"}),
    "compress": (True, {"mode": "streamed"}),
    "channel_inflight": (7, {"mode": "streamed"}),
    "channel_fault": (object(), {"mode": "streamed"}),
}


@pytest.mark.parametrize("kwarg", sorted(LEGACY_KWARGS))
def test_every_legacy_kwarg_maps_to_its_config_field(kwarg):
    value, extra = _PROBES[kwarg]
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        cfg = EngineConfig.resolve(None, {kwarg: value, **extra})
    deps = [w for w in caught if issubclass(w.category, DeprecationWarning)]
    assert len(deps) == 1, "exactly one DeprecationWarning per construction"
    assert kwarg in str(deps[0].message)
    sub, attr = LEGACY_KWARGS[kwarg]
    target = cfg if sub is None else getattr(cfg, sub)
    assert getattr(target, attr) == value


def test_probe_table_covers_every_legacy_kwarg():
    assert set(_PROBES) == set(LEGACY_KWARGS)


def test_new_surface_emits_no_warning(small):
    _, pg = small
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        GraphDEngine(pg, PageRank(supersteps=2), config=EngineConfig())
    assert not [w for w in caught
                if issubclass(w.category, DeprecationWarning)]


def test_legacy_engine_kwargs_still_work_and_warn_once(small):
    _, pg = small
    with pytest.warns(DeprecationWarning) as caught:
        eng = GraphDEngine(pg, PageRank(supersteps=2), mode="basic",
                           adapt_threshold=0.3)
    assert len([w for w in caught
                if issubclass(w.category, DeprecationWarning)]) == 1
    assert eng.mode == "basic"
    assert eng.config.adapt_threshold == 0.3


def test_legacy_positional_mode_still_works(small):
    _, pg = small
    with pytest.warns(DeprecationWarning):
        eng = GraphDEngine(pg, PageRank(supersteps=2), "basic")
    assert eng.mode == "basic"


def test_legacy_and_config_surfaces_build_identical_engines(tmp_path):
    g = rmat_graph(scale=6, edge_factor=6, seed=11)
    pgs, _, store = partition_graph_streamed(
        g, 3, str(tmp_path / "s"), edge_block=32
    )
    with pytest.warns(DeprecationWarning):
        old = GraphDEngine(
            pgs, HashMin(), mode="streamed", stream_store=store,
            stream_chunk_blocks=2, msg_read_chunk=128, pipeline=True,
            channel_inflight=2,
        )
    new = GraphDEngine(
        pgs, HashMin(),
        config=EngineConfig(
            mode="streamed",
            stream=StreamConfig(chunk_blocks=2),
            spill=MessageSpillConfig(read_chunk=128),
            channel=ChannelConfig(pipeline=True, inflight=2),
        ),
        stream_store=store,
    )
    assert old.config == new.config
    assert old.memory_model() == new.memory_model()


def test_conflicting_kwarg_and_config_raises(small):
    _, pg = small
    cfg = EngineConfig(mode="basic")
    with pytest.raises(ConfigError, match="conflicting"):
        GraphDEngine(pg, PageRank(supersteps=2), config=cfg, mode="basic")
    with pytest.raises(ConfigError, match="stream.chunk_blocks"):
        GraphDEngine(pg, PageRank(supersteps=2), config=cfg,
                     stream_chunk_blocks=4)


def test_unknown_kwarg_raises_type_error(small):
    _, pg = small
    with pytest.raises(TypeError, match="unknow"):
        GraphDEngine(pg, PageRank(supersteps=2), strem_chunk_blocks=4)


# ---------------------------------------------------------------------------
# validation ownership: field checks in validate(), cross-config in finalize()
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bad, match", [
    (dict(mode="warp"), "unknown mode"),
    (dict(backend="cuda"), "unknown backend"),
    (dict(stream=StreamConfig(chunk_blocks=0)), "chunk_blocks"),
    (dict(stream=StreamConfig(depth=0)), "depth"),
    (dict(spill=MessageSpillConfig(slice_cap=0)), "slice_cap"),
    (dict(spill=MessageSpillConfig(merge_fanin=1)), "merge_fanin"),
    (dict(channel=ChannelConfig(inflight=0)), "inflight"),
    (dict(channel=ChannelConfig(pipeline=True)), "streamed-mode knobs"),
    (dict(channel=ChannelConfig(compress=True)), "streamed-mode knobs"),
    (dict(mode="streamed", backend="pallas"), "needs mode='recoded'"),
    (dict(recovery=RecoveryConfig(log_messages=True)), "checkpoint cadence"),
    (dict(sparse_cap_frac=0.0), "sparse_cap_frac"),
])
def test_invalid_configs_raise(bad, match):
    with pytest.raises(ConfigError, match=match):
        EngineConfig(**bad).finalize()


def test_engine_level_checks_still_fire(small, tmp_path):
    """Checks needing the program/partition stayed in the engine."""
    _, pg = small
    from repro.core import DistinctInLabels

    with pytest.raises(ValueError, match="combiner"):
        GraphDEngine(pg, DistinctInLabels(n_groups=4),
                     config=EngineConfig(mode="recoded"))
    with pytest.raises(ValueError, match="stream_store"):
        GraphDEngine(pg, PageRank(supersteps=2),
                     config=EngineConfig(mode="streamed"))


def test_config_json_round_trip():
    cfg = EngineConfig(
        mode="streamed",
        stream=StreamConfig(chunk_blocks=2, depth=3),
        spill=MessageSpillConfig(slice_cap=256, read_chunk=128,
                                 merge_fanin=4),
        channel=ChannelConfig(pipeline=True, compress=True, inflight=2),
        recovery=RecoveryConfig(checkpoint_every=5, log_messages=True),
    )
    assert EngineConfig.from_json(cfg.to_json()) == cfg
