"""Typed-config surface: field validation, cross-config invariants, and the
regression guard that the PR-4 flat-kwarg deprecation shim is really gone
(flat kwargs and the positional mode string now raise ``ConfigError``)."""

import warnings

import pytest

from repro.core import (
    ConfigError, EngineConfig, GraphDEngine, HashMin, PageRank,
)
from repro.core.config import (
    ChannelConfig, MessageSpillConfig, RecoveryConfig, StreamConfig,
)
from repro.graph import partition_graph, rmat_graph


@pytest.fixture(scope="module")
def small():
    g = rmat_graph(scale=6, edge_factor=6, seed=11)
    pg, rmap = partition_graph(g, n_shards=3, edge_block=32)
    return g, pg


# ---------------------------------------------------------------------------
# the shim is gone: flat kwargs are a hard error, not a warning
# ---------------------------------------------------------------------------

def test_flat_kwargs_raise_config_error(small):
    """The one-release deprecation window (PR 4) is over: every legacy flat
    kwarg — and the positional mode string — is now a ConfigError naming
    the typed surface."""
    _, pg = small
    with pytest.raises(ConfigError, match="EngineConfig"):
        GraphDEngine(pg, PageRank(supersteps=2), mode="basic")
    with pytest.raises(ConfigError, match="pipeline"):
        GraphDEngine(pg, PageRank(supersteps=2), pipeline=True,
                     stream_chunk_blocks=4)
    with pytest.raises(ConfigError, match="EngineConfig"):
        GraphDEngine(pg, PageRank(supersteps=2), "basic")
    # typos die loudly too (they used to be TypeError from the shim's table)
    with pytest.raises(ConfigError, match="strem_chunk_blocks"):
        GraphDEngine(pg, PageRank(supersteps=2), strem_chunk_blocks=4)


def test_new_surface_emits_no_warning(small):
    _, pg = small
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        GraphDEngine(pg, PageRank(supersteps=2), config=EngineConfig())
    assert not [w for w in caught
                if issubclass(w.category, DeprecationWarning)]


# ---------------------------------------------------------------------------
# validation ownership: field checks in validate(), cross-config in finalize()
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bad, match", [
    (dict(mode="warp"), "unknown mode"),
    (dict(backend="cuda"), "unknown backend"),
    (dict(stream=StreamConfig(chunk_blocks=0)), "chunk_blocks"),
    (dict(stream=StreamConfig(depth=0)), "depth"),
    (dict(spill=MessageSpillConfig(slice_cap=0)), "slice_cap"),
    (dict(spill=MessageSpillConfig(merge_fanin=1)), "merge_fanin"),
    (dict(channel=ChannelConfig(inflight=0)), "inflight"),
    (dict(channel=ChannelConfig(pipeline=True)), "streamed-mode knobs"),
    (dict(channel=ChannelConfig(compress=True)), "streamed-mode knobs"),
    (dict(mode="streamed", backend="pallas"), "needs mode='recoded'"),
    (dict(recovery=RecoveryConfig(log_messages=True)), "checkpoint cadence"),
    (dict(sparse_cap_frac=0.0), "sparse_cap_frac"),
    # the auto payload pick resolves its codec from a first-superstep
    # sample; a message log needs one fixed wire format for replay — the
    # conflict must be named at finalize(), not silently dropped
    (dict(mode="streamed",
          channel=ChannelConfig(pipeline=True, compress_payload="auto"),
          recovery=RecoveryConfig(checkpoint_every=2, log_messages=True)),
     "bit-identical replay"),
])
def test_invalid_configs_raise(bad, match):
    with pytest.raises(ConfigError, match=match):
        EngineConfig(**bad).finalize()


def test_engine_level_checks_still_fire(small, tmp_path):
    """Checks needing the program/partition stayed in the engine."""
    _, pg = small
    from repro.core import DistinctInLabels

    with pytest.raises(ValueError, match="combiner"):
        GraphDEngine(pg, DistinctInLabels(n_groups=4),
                     config=EngineConfig(mode="recoded"))
    with pytest.raises(ValueError, match="stream_store"):
        GraphDEngine(pg, PageRank(supersteps=2),
                     config=EngineConfig(mode="streamed"))


def test_config_json_round_trip():
    cfg = EngineConfig(
        mode="streamed",
        stream=StreamConfig(chunk_blocks=2, depth=3),
        spill=MessageSpillConfig(slice_cap=256, read_chunk=128,
                                 merge_fanin=4),
        channel=ChannelConfig(pipeline=True, compress=True, inflight=2),
        recovery=RecoveryConfig(checkpoint_every=5, log_messages=True),
    )
    assert EngineConfig.from_json(cfg.to_json()) == cfg
