"""GraphDJob session facade: one call owns plan -> partition/spill ->
engine -> run -> JobResult, plus single-shard recovery and elastic rescale,
with planned-vs-realized memory accounting that round-trips to JSON."""

import dataclasses
import json
import os

import numpy as np
import pytest

from repro.core import (
    EngineConfig, GraphDEngine, GraphDJob, GraphMeta, HashMin, MemoryBudget,
    PageRank, estimate_memory, plan,
)
from repro.core.plan import ram_total
from repro.graph import partition_graph, partition_graph_streamed, rmat_graph

N = 3
EDGE_BLOCK = 32


@pytest.fixture(scope="module")
def graph():
    return rmat_graph(scale=8, edge_factor=8, seed=9)


def _streamed_budget(graph, prog=None):
    """A budget the planner maps to plain streamed mode for this graph:
    one byte below what keeping the edge groups resident would need."""
    loose = plan(prog or HashMin(), graph, MemoryBudget(n_shards=N),
                 edge_block=EDGE_BLOCK)
    rec = next(c for c in loose.alternatives if c.name == "recoded")
    return MemoryBudget(ram_per_shard=rec.ram_total - 1, n_shards=N)


def test_job_default_budget_runs_in_memory(graph):
    with GraphDJob(HashMin(), graph, budget=MemoryBudget(n_shards=N),
                   edge_block=EDGE_BLOCK) as job:
        assert job.plan.mode == "recoded"
        assert job.store is None  # nothing spilled for in-memory plans
        res = job.run()
    pg, _ = partition_graph(graph, n_shards=N, edge_block=EDGE_BLOCK)
    eng = GraphDEngine(pg, HashMin(), config=EngineConfig())
    (values, _), hist = eng.run()
    assert res.values == eng.gather_values(values)
    assert res.n_supersteps == len(hist)


def test_job_streamed_budget_spills_under_workdir(graph, tmp_path):
    wd = str(tmp_path / "job")
    with GraphDJob(HashMin(), graph, budget=_streamed_budget(graph),
                   edge_block=EDGE_BLOCK, workdir=wd) as job:
        assert job.plan.mode == "streamed"
        assert job.store is not None
        assert job.store.dir.startswith(wd)  # spilled automatically
        res = job.run()
        assert res.realized_ram <= job.plan.budget.ram_per_shard
    # bit-identical to the in-memory reference (HashMin is order-insensitive)
    pg, _ = partition_graph(graph, n_shards=N, edge_block=EDGE_BLOCK)
    eng = GraphDEngine(pg, HashMin(), config=EngineConfig(mode="basic"))
    (values, _), _ = eng.run()
    assert res.values == eng.gather_values(values)
    # user-supplied workdir is preserved on close
    assert os.path.isdir(wd)


def test_job_result_summary_is_json_round_trippable(graph):
    with GraphDJob(PageRank(supersteps=3), graph,
                   budget=MemoryBudget(n_shards=N),
                   edge_block=EDGE_BLOCK) as job:
        res = job.run()
    s = json.loads(res.to_json())
    assert s["mode"] == "recoded"
    assert s["n_supersteps"] == 3
    assert s["planned"]["ram"] == res.plan.ram_total
    assert s["realized"]["ram"] == res.realized_ram
    assert s["planned_over_realized_ram"] > 0
    assert len(s["history"]) == 3
    assert s["history"][0]["step"] == 0
    # the plan itself serializes alongside (the CI artifact pair)
    json.loads(res.plan.to_json())


def test_job_plan_and_budget_are_mutually_exclusive(graph):
    p = plan(HashMin(), graph, MemoryBudget(n_shards=N))
    with pytest.raises(ValueError, match="not both"):
        GraphDJob(HashMin(), graph, budget=MemoryBudget(n_shards=N), plan=p)


def test_job_expert_plan_override(graph, tmp_path):
    """The expert path: hand the job a pre-built (possibly hand-edited)
    plan; the job materializes exactly that physical layout."""
    p = plan(HashMin(), graph, _streamed_budget(graph),
             edge_block=EDGE_BLOCK)
    p = dataclasses.replace(p, config=dataclasses.replace(
        p.config, stream=dataclasses.replace(p.config.stream,
                                             chunk_blocks=2)))
    with GraphDJob(HashMin(), graph, plan=p,
                   workdir=str(tmp_path / "j")) as job:
        assert job.engine._stream_reader.chunk_blocks == 2
        job.run()


def test_job_recovery_single_shard(graph, tmp_path):
    with GraphDJob(HashMin(), graph, budget=_streamed_budget(graph),
                   edge_block=EDGE_BLOCK, workdir=str(tmp_path / "j"),
                   checkpoint_every=2) as job:
        res = job.run()
        full = np.asarray(job._state[0])
        for failed in (0, 2):
            v, a = job.recover_shard(failed)
            assert np.array_equal(np.asarray(v), full[failed])


def test_job_recovery_works_right_after_rescale(graph, tmp_path):
    """The rescaled lineage gets a fresh ckpt/log namespace; recovery must
    work immediately — the rescale seeds a base checkpoint with the
    migrated state, not just at the next cadence boundary."""
    with GraphDJob(HashMin(), graph, budget=MemoryBudget(n_shards=N),
                   edge_block=EDGE_BLOCK, workdir=str(tmp_path / "j"),
                   checkpoint_every=3) as job:
        job.run(max_supersteps=2)
        res = job.rescale(4).run(max_supersteps=1)  # no cadence step lands
        v, a = job.recover_shard(1)
        vmask = np.asarray(job.pg.vmask)[1]
        ids = np.asarray(job.pg.old_ids)[1][vmask]
        ref = np.array([res.values[int(i)] for i in ids])
        assert np.array_equal(np.asarray(v)[vmask], ref)


def test_job_recovery_requires_recovery_config(graph):
    with GraphDJob(HashMin(), graph, budget=MemoryBudget(n_shards=N),
                   edge_block=EDGE_BLOCK) as job:
        job.run()
        with pytest.raises(RuntimeError, match="checkpoint_every"):
            job.recover_shard(0)


def test_job_rescale_continues_and_matches_uninterrupted(graph, tmp_path):
    prog = lambda: HashMin()
    with GraphDJob(prog(), graph, budget=MemoryBudget(n_shards=N),
                   edge_block=EDGE_BLOCK) as job:
        job.run(max_supersteps=2)
        res = job.rescale(5).run()
        assert job.plan.n_shards == 5
    # reference: uninterrupted run on the original shard count — HashMin
    # labels fold the step-0 init, so values keyed by ORIGINAL id must match
    with GraphDJob(prog(), graph, budget=MemoryBudget(n_shards=N),
                   edge_block=EDGE_BLOCK) as ref_job:
        ref = ref_job.run()
    assert res.values == ref.values
    assert res.history[-1].step == ref.history[-1].step


def test_job_rescale_streamed_respills(graph, tmp_path):
    """Rescaling an out-of-core job: the old partition is vertex-only (its
    edges live on disk), so migration must go through original ids and the
    new lineage must respill its own edge streams under the workdir."""
    with GraphDJob(HashMin(), graph, budget=_streamed_budget(graph),
                   edge_block=EDGE_BLOCK,
                   workdir=str(tmp_path / "j")) as job:
        assert job.plan.mode == "streamed"
        job.run(max_supersteps=2)
        old_store_dir = job.store.dir
        res = job.rescale(5).run()
        if job.plan.mode == "streamed":  # re-planned for the same budget
            assert job.store.dir != old_store_dir
            assert job.store.geom.n_shards == 5
    with GraphDJob(HashMin(), graph, budget=_streamed_budget(graph),
                   edge_block=EDGE_BLOCK,
                   workdir=str(tmp_path / "ref")) as ref_job:
        ref = ref_job.run()
    assert res.values == ref.values


def test_job_workdir_identity_guard(graph, tmp_path):
    """A reused workdir holding another job's checkpoints must be refused,
    not silently restored as this program's state."""
    wd = str(tmp_path / "shared")
    with GraphDJob(HashMin(), graph, budget=MemoryBudget(n_shards=N),
                   edge_block=EDGE_BLOCK, workdir=wd,
                   checkpoint_every=2) as job:
        job.run(max_supersteps=4)
    with pytest.raises(ValueError, match="different job"):
        GraphDJob(PageRank(supersteps=6), graph,
                  budget=MemoryBudget(n_shards=N),
                  edge_block=EDGE_BLOCK, workdir=wd, checkpoint_every=2)
    # the SAME job in the same workdir is a resume, not an error
    with GraphDJob(HashMin(), graph, budget=MemoryBudget(n_shards=N),
                   edge_block=EDGE_BLOCK, workdir=wd,
                   checkpoint_every=2) as again:
        again.run(max_supersteps=4)


def test_job_combinerless_checkpointing_on_in_memory_plan(graph):
    """checkpoint_every with a combiner-less program on an in-memory plan:
    message logging has no representation there (no combined A_s, no OMS
    runs), so the job wires checkpoints only — and says so when recovery
    is then asked for."""
    from repro.core import DistinctInLabels

    with GraphDJob(DistinctInLabels(n_groups=8, rounds=2), graph,
                   budget=MemoryBudget(n_shards=N), edge_block=EDGE_BLOCK,
                   checkpoint_every=1) as job:
        assert job.plan.mode == "basic"
        assert job.message_log is None  # logging degraded, not crashed
        assert job.checkpointer is not None
        job.run()
        with pytest.raises(RuntimeError, match="checkpoint_every"):
            job.recover_shard(0)


def test_job_tempdir_cleanup(graph):
    job = GraphDJob(HashMin(), graph, budget=MemoryBudget(n_shards=N),
                    edge_block=EDGE_BLOCK)
    wd = job.workdir
    job.run(max_supersteps=1)
    job.close()
    assert not os.path.exists(wd)  # job-owned tempdir released
    with pytest.raises(RuntimeError, match="closed"):
        job.run()


# -- workdir/scratch lifecycle on exception paths ---------------------------

def test_job_build_failure_does_not_strand_tempdir(graph, monkeypatch):
    """A failure between partition-spill and engine wiring must not leak the
    job-owned tempdir (half-written edge spills included)."""
    import repro.core.job as jobmod

    def boom(graph, plan, directory):
        boom.edges_dir = directory
        os.makedirs(directory, exist_ok=True)  # simulate a partial spill
        with open(os.path.join(directory, "partial.bin"), "wb") as f:
            f.write(b"\0" * 64)
        raise RuntimeError("disk full mid-spill")

    monkeypatch.setattr(jobmod, "partition_for_plan", boom)
    with pytest.raises(RuntimeError, match="disk full"):
        GraphDJob(HashMin(), graph, budget=MemoryBudget(n_shards=N),
                  edge_block=EDGE_BLOCK)
    workdir = os.path.dirname(boom.edges_dir)
    assert not os.path.exists(workdir)  # tempdir swept, not stranded


def test_job_build_failure_keeps_user_workdir_but_closes_job(
        graph, tmp_path, monkeypatch):
    """With an explicit user workdir the partial spill is kept for
    post-mortem, but the job object is unusable (closed)."""
    import repro.core.job as jobmod

    real = jobmod.partition_for_plan

    def boom(graph, plan, directory):
        raise RuntimeError("spill interrupted")

    monkeypatch.setattr(jobmod, "partition_for_plan", boom)
    wd = str(tmp_path / "kept")
    with pytest.raises(RuntimeError, match="spill interrupted"):
        GraphDJob(HashMin(), graph, budget=MemoryBudget(n_shards=N),
                  edge_block=EDGE_BLOCK, workdir=wd)
    assert os.path.exists(wd)  # user dir survives for inspection
    monkeypatch.setattr(jobmod, "partition_for_plan", real)
    # and the workdir is reusable by a fresh job afterwards
    with GraphDJob(HashMin(), graph, budget=MemoryBudget(n_shards=N),
                   edge_block=EDGE_BLOCK, workdir=wd) as job:
        job.run(max_supersteps=1)


def test_job_sweeps_scratch_after_failed_superstep(graph, tmp_path):
    """A sender crash mid-superstep leaves a torn inbox step dir; run()'s
    failure path must sweep it so a user workdir never accumulates
    half-written run files."""
    from repro.core import ChannelConfig, StreamConfig
    from repro.streams import ChannelError, FaultPoint

    base = plan(HashMin(), graph, _streamed_budget(graph),
                edge_block=EDGE_BLOCK)
    assert base.mode == "streamed"
    cfg = dataclasses.replace(
        base.config,
        channel=ChannelConfig(pipeline=True,
                              fault=FaultPoint(after_packets=2)),
    )
    broken = dataclasses.replace(base, config=cfg)
    job = GraphDJob(HashMin(), graph, plan=broken,
                    workdir=str(tmp_path / "torn"))
    with pytest.raises(ChannelError):
        job.run()
    inbox = os.path.join(job.store.dir, "inbox")
    assert not os.path.isdir(inbox) or not [
        n for n in os.listdir(inbox) if n.startswith("step-")
    ]
    job.close()


# -- launch="processes" ------------------------------------------------------

def test_job_launch_knob_validation(graph):
    with pytest.raises(ValueError, match="launch"):
        GraphDJob(HashMin(), graph, budget=MemoryBudget(n_shards=N),
                  launch="cluster")
    # an in-memory plan cannot be deployed as processes
    p = plan(HashMin(), graph, MemoryBudget(n_shards=N),
             edge_block=EDGE_BLOCK)
    assert p.mode != "streamed"
    with pytest.raises(ValueError, match="streamed"):
        GraphDJob(HashMin(), graph, plan=p, launch="processes")


def test_job_processes_planner_vetoes_and_launch_field(graph):
    p = plan(HashMin(), graph, MemoryBudget(n_shards=N),
             edge_block=EDGE_BLOCK, launch="processes")
    assert p.launch == "processes"
    assert p.mode == "streamed" and p.pipeline
    assert p.config.channel.full_duplex
    # every non-deployable candidate is vetoed with a reason, not hidden
    rejected = {c.name: c for c in p.alternatives if not c.feasible}
    assert "recoded" in rejected
    assert "streamed" in rejected  # the unpipelined fold
    assert "processes" in rejected["recoded"].reason
    # the launch knob survives the plan's JSON round trip
    from repro.core.plan import ExecutionPlan
    assert ExecutionPlan.from_json(p.to_json()).launch == "processes"


def test_job_processes_auto_payload_downgrades_to_lossless(graph, tmp_path):
    """``compress_payload="auto"`` under ``launch="processes"``: n worker
    processes would each sample and decide independently and diverge, so
    the job facade downgrades the plan to the fixed lossless codec — the
    compression survives, only the sampling is dropped."""
    p = plan(HashMin(), graph, MemoryBudget(n_shards=N),
             edge_block=EDGE_BLOCK, launch="processes")
    p = dataclasses.replace(p, config=dataclasses.replace(
        p.config, channel=dataclasses.replace(
            p.config.channel, compress_payload="auto")))
    assert p.config.channel.payload_scheme == "auto"
    job = GraphDJob(HashMin(), graph, plan=p, launch="processes",
                    workdir=str(tmp_path / "auto"))
    assert job.plan.config.channel.payload_scheme == "lossless"
    job.close()
    # ... while the threaded launch keeps the auto-pick untouched
    p2 = plan(HashMin(), graph, MemoryBudget(n_shards=N),
              edge_block=EDGE_BLOCK, launch="processes")
    p2 = dataclasses.replace(p2, config=dataclasses.replace(
        p2.config, channel=dataclasses.replace(
            p2.config.channel, compress_payload="auto")))
    jt = GraphDJob(HashMin(), graph, plan=p2,
                   workdir=str(tmp_path / "threads"))
    assert jt.plan.config.channel.payload_scheme == "auto"
    jt.close()


def test_job_processes_run_resume_and_memory_budget(graph, tmp_path):
    """A paused processes job resumes from live state; the realized
    per-process RAM honors the budget the planner promised it under."""
    import copy

    loose = plan(HashMin(), graph, MemoryBudget(n_shards=N),
                 edge_block=EDGE_BLOCK, launch="processes")
    budget = MemoryBudget(ram_per_shard=loose.ram_total, n_shards=N)
    ref = GraphDJob(HashMin(), graph, plan=copy.deepcopy(loose),
                    workdir=str(tmp_path / "ref"))
    r_ref = ref.run()

    job = GraphDJob(HashMin(), graph, budget=budget,
                    edge_block=EDGE_BLOCK, launch="processes",
                    workdir=str(tmp_path / "procs"))
    assert job.plan.launch == "processes"
    first = job.run(max_supersteps=2)
    assert first.n_supersteps == 2
    second = job.run()  # resumes from the live state at step 2
    assert second.history[0].step == 2
    assert second.values == r_ref.values  # bit-identical across the pause
    # the per-process memory model stays inside the planner's budget
    assert second.realized_ram <= budget.ram_per_shard
    # transport scratch was swept; durable artifacts (spec, results) remain
    procs_dir = job._dir("procs", "")
    assert not os.path.exists(os.path.join(procs_dir, "outbox"))
    assert not os.path.exists(os.path.join(procs_dir, "announce"))
    ref.close()
    job.close()
