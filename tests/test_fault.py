"""Fault-tolerance drills: checkpoint/restore, message-log fast recovery,
elastic repartitioning (paper §3.4 + [19]), and deterministic crash
injection into the pipelined sender (streams/channel.py)."""

import os

import numpy as np
import pytest

from repro.core import (
    ChannelConfig, DistinctInLabels, EngineConfig, GraphDEngine, HashMin,
    PageRank, SSSP,
)
from repro.core.checkpoint import (
    Checkpointer, MessageLog, RunFileMessageLog, recover_shard,
    recover_shard_streamed,
)
from repro.core.elastic import extract_global, repartition
from repro.graph import partition_graph, partition_graph_streamed, rmat_graph
from repro.streams import ChannelError, FaultPoint


@pytest.fixture
def job():
    g = rmat_graph(scale=7, edge_factor=8, seed=3)
    pg, rmap = partition_graph(g, n_shards=4, edge_block=64)
    return g, pg, rmap


class TestCheckpoint:
    def test_save_restore_roundtrip(self, job, tmp_path):
        _, pg, _ = job
        eng = GraphDEngine(pg, PageRank(supersteps=6))
        ck = Checkpointer(str(tmp_path / "ckpt"), every=2)
        (v, a), _ = eng.run(checkpointer=ck)
        assert ck.latest() == 6
        rv, ra, step = ck.restore()
        (v6, a6), _ = eng.run(max_supersteps=6)
        assert np.allclose(np.asarray(rv), np.asarray(v6))

    def test_restart_equals_uninterrupted(self, job, tmp_path):
        _, pg, _ = job
        (v_ref, _), _ = GraphDEngine(pg, PageRank(supersteps=8)).run()
        ck = Checkpointer(str(tmp_path / "ckpt"), every=3)
        eng = GraphDEngine(pg, PageRank(supersteps=8))
        eng.run(max_supersteps=5, checkpointer=ck)  # "crash" after step 5
        eng2 = GraphDEngine(pg, PageRank(supersteps=8))
        (v2, _), hist = eng2.run(checkpointer=ck)  # resumes from step 3
        assert hist[0].step == 3
        assert np.allclose(np.asarray(v2), np.asarray(v_ref))

    def test_gc_keeps_latest(self, job, tmp_path):
        _, pg, _ = job
        ck = Checkpointer(str(tmp_path / "ckpt"), every=1, keep=2)
        eng = GraphDEngine(pg, PageRank(supersteps=6))
        eng.run(checkpointer=ck)
        assert len(ck.all_steps()) == 2

    def test_atomic_no_partial_visible(self, job, tmp_path):
        _, pg, _ = job
        ck = Checkpointer(str(tmp_path / "ckpt"), every=1)
        eng = GraphDEngine(pg, PageRank(supersteps=3))
        eng.run(checkpointer=ck)
        for name in os.listdir(str(tmp_path / "ckpt")):
            assert not name.startswith(".tmp")

    def test_stale_tmp_dirs_swept_on_init(self, tmp_path):
        """A crash between makedirs(tmp) and the atomic rename used to leak
        .tmp-step-* directories forever; __init__ sweeps them."""
        d = str(tmp_path / "ckpt")
        os.makedirs(os.path.join(d, ".tmp-step-000004"))
        with open(os.path.join(d, ".tmp-step-000004", "shard-0.npz"), "wb"):
            pass
        ck = Checkpointer(d, every=1)
        assert not any(
            name.startswith(".tmp") for name in os.listdir(d)
        )
        assert ck.all_steps() == []

    def test_all_steps_ignores_malformed_entries(self, tmp_path):
        d = str(tmp_path / "ckpt")
        ck = Checkpointer(d, every=1)
        os.makedirs(os.path.join(d, "step-000002"))
        os.makedirs(os.path.join(d, "step-garbage"))  # used to raise
        with open(os.path.join(d, "step-000009"), "w"):
            pass  # a FILE named like a step is not a checkpoint
        with open(os.path.join(d, "notes.txt"), "w"):
            pass
        assert ck.all_steps() == [2]
        assert ck.latest() == 2

    def test_explicit_state_wins_over_checkpoint(self, job, tmp_path):
        """run(state=..., start_step=...) must NOT be silently discarded
        when the checkpoint directory already has a newer snapshot."""
        _, pg, _ = job
        ck = Checkpointer(str(tmp_path / "ckpt"), every=2)
        eng = GraphDEngine(pg, PageRank(supersteps=6))
        eng.run(checkpointer=ck)  # leaves a step-6 checkpoint behind
        assert ck.latest() == 6
        v0, a0 = eng.init()
        (_, _), hist = eng.run(state=(v0, a0), start_step=0,
                               checkpointer=ck)
        assert hist[0].step == 0  # not fast-forwarded to 6
        assert hist[0].restored_from is None

    def test_auto_restore_records_step(self, job, tmp_path):
        _, pg, _ = job
        ck = Checkpointer(str(tmp_path / "ckpt"), every=2)
        eng = GraphDEngine(pg, PageRank(supersteps=6))
        eng.run(max_supersteps=4, checkpointer=ck)
        (_, _), hist = GraphDEngine(pg, PageRank(supersteps=6)).run(
            checkpointer=ck
        )
        assert hist[0].step == 4
        assert hist[0].restored_from == 4
        assert all(r.restored_from is None for r in hist[1:])


class TestFastRecovery:
    """[19]: only the failed shard recomputes, replaying logged messages."""

    @pytest.mark.parametrize("failed", [0, 2, 3])
    def test_single_shard_recovery(self, job, tmp_path, failed):
        _, pg, _ = job
        prog = PageRank(supersteps=8)
        (v_ref, a_ref), _ = GraphDEngine(pg, prog).run()
        ck = Checkpointer(str(tmp_path / "ckpt"), every=3)
        ml = MessageLog(str(tmp_path / "logs"))
        eng = GraphDEngine(pg, prog, message_log=ml)
        ck.save(0, *eng.init())
        eng.run(checkpointer=ck)
        vj, aj = recover_shard(pg, prog, failed=failed, ckpt=ck, log=ml,
                               target_step=8)
        assert np.abs(
            np.asarray(vj) - np.asarray(v_ref)[failed]
        ).max() < 1e-6
        assert np.array_equal(np.asarray(aj), np.asarray(a_ref)[failed])

    def test_recovery_min_combiner(self, job, tmp_path):
        _, pg, _ = job
        prog = HashMin()
        (v_ref, _), hist = GraphDEngine(pg, prog).run()
        steps = len(hist)
        ck = Checkpointer(str(tmp_path / "ckpt"), every=4)
        ml = MessageLog(str(tmp_path / "logs"))
        eng = GraphDEngine(pg, prog, message_log=ml)
        ck.save(0, *eng.init())
        eng.run(checkpointer=ck)
        vj, _ = recover_shard(pg, prog, failed=1, ckpt=ck, log=ml,
                              target_step=steps)
        assert np.array_equal(np.asarray(vj), np.asarray(v_ref)[1])

    def test_log_gc(self, job, tmp_path):
        _, pg, _ = job
        ml = MessageLog(str(tmp_path / "logs"))
        eng = GraphDEngine(pg, PageRank(supersteps=4), message_log=ml)
        eng.run()
        ml.gc_before(2)
        remaining = sorted(os.listdir(str(tmp_path / "logs")))
        assert remaining == ["step-000002", "step-000003"]

    def test_engine_gcs_logs_after_checkpoint(self, job, tmp_path):
        """Regression: gc_before was never invoked — OMS logs grew without
        bound. The driver must GC right after each durable checkpoint
        (paper §3.4: keep OMSs until a new checkpoint is written)."""
        _, pg, _ = job
        ck = Checkpointer(str(tmp_path / "ckpt"), every=3)
        ml = MessageLog(str(tmp_path / "logs"))
        eng = GraphDEngine(pg, PageRank(supersteps=8), message_log=ml)
        eng.run(checkpointer=ck)
        # checkpoints landed at steps 3 and 6 => logs 0..5 are gone, and
        # recovery from the latest checkpoint still has every log it needs
        assert sorted(os.listdir(str(tmp_path / "logs"))) == [
            "step-000006", "step-000007",
        ]
        vj, _ = recover_shard(pg, PageRank(supersteps=8), failed=1, ckpt=ck,
                              log=ml, target_step=8)
        (v_ref, _), _ = GraphDEngine(pg, PageRank(supersteps=8)).run()
        assert np.abs(
            np.asarray(vj) - np.asarray(v_ref)[1]
        ).max() < 1e-6


class TestElastic:
    def test_scale_up_pagerank(self, job):
        _, pg, _ = job
        (v_ref, _), _ = GraphDEngine(pg, PageRank(supersteps=8)).run()
        ref = GraphDEngine(pg, PageRank(supersteps=8)).gather_values(v_ref)
        engA = GraphDEngine(pg, PageRank(supersteps=8))
        (vA, aA), _ = engA.run(max_supersteps=4)
        pgB, vB, aB = repartition(pg, vA, aA, n_new=6, edge_block=64)
        engB = GraphDEngine(pgB, PageRank(supersteps=8))
        (vC, _), _ = engB.run(state=(vB, aB), start_step=4)
        got = engB.gather_values(vC)
        assert max(abs(got[k] - ref[k]) for k in ref) < 1e-6

    def test_scale_down_hashmin(self, job):
        g, pg, _ = job
        gu = rmat_graph(scale=8, edge_factor=2, seed=9, directed=False)
        pgu, _ = partition_graph(gu, n_shards=4, edge_block=32)
        (vr, _), _ = GraphDEngine(pgu, HashMin()).run()
        want = GraphDEngine(pgu, HashMin()).gather_values(vr)
        e1 = GraphDEngine(pgu, HashMin())
        (v1, a1), _ = e1.run(max_supersteps=3)
        pg2, v2, a2 = repartition(pgu, v1, a1, n_new=2, edge_block=32)
        e2 = GraphDEngine(pg2, HashMin())
        (v3, _), _ = e2.run(state=(v2, a2), start_step=3)
        assert e2.gather_values(v3) == want

    def test_extract_global_roundtrip(self, job):
        g, pg, rmap = job
        eng = GraphDEngine(pg, PageRank(supersteps=2))
        (v, a), _ = eng.run()
        g_real, old_real, val_real, act_real, src_g, dst_g, w_g = (
            extract_global(pg, v, a)
        )
        assert len(g_real) == g.n_vertices
        assert len(src_g) == g.n_edges
        # repartition to the SAME n is an identity on results
        pg2, v2, a2 = repartition(pg, v, a, n_new=pg.n_shards,
                                  edge_block=pg.edge_block)
        got = GraphDEngine(pg2, PageRank(supersteps=2)).gather_values(v2)
        want = eng.gather_values(v)
        assert got == want

    def test_sssp_across_repartition(self, job):
        g, pg, rmap = job
        src_new = int(rmap.to_new(np.array([int(g.vertex_ids[0])]))[0])
        (v_ref, _), _ = GraphDEngine(pg, SSSP(src_new)).run()
        ref = GraphDEngine(pg, SSSP(src_new)).gather_values(v_ref)
        e1 = GraphDEngine(pg, SSSP(src_new))
        (v1, a1), _ = e1.run(max_supersteps=2)
        pg2, v2, a2 = repartition(pg, v1, a1, n_new=5, edge_block=64)
        e2 = GraphDEngine(pg2, SSSP(src_new))
        (v3, _), _ = e2.run(state=(v2, a2), start_step=2)
        got = e2.gather_values(v3)
        for k in ref:
            assert got[k] == ref[k] or (
                np.isinf(got[k]) and np.isinf(ref[k])
            )


# ---------------------------------------------------------------------------
# crash injection into the pipelined sender (ISSUE 3: kill the thread
# mid-superstep, recovery must replay to the same state)
# ---------------------------------------------------------------------------

@pytest.fixture
def streamed_job(tmp_path):
    g = rmat_graph(scale=7, edge_factor=6, seed=3)
    pgs, rmap, store = partition_graph_streamed(
        g, 4, str(tmp_path / "spill"), edge_block=64
    )
    return g, pgs, rmap, store


@pytest.fixture
def fault_point():
    """Deterministic fault: the sender dies after exactly 40 transmitted
    packets. PageRank on 4 fully-active shards ships 16 group packets per
    superstep, so this lands MID-superstep 2 (packet 8 of 16) — after the
    step-2 checkpoint is durable, before the step's inbox is complete."""
    return FaultPoint(after_packets=40)


class TestStreamedCrashInjection:
    def test_sender_crash_surfaces_midstep_then_rerun_matches(
        self, streamed_job, tmp_path, fault_point
    ):
        _, pgs, _, store = streamed_job
        mk = lambda: PageRank(supersteps=6)
        (v_ref, a_ref), _ = GraphDEngine(
                                pgs,
                                mk(),
                                config=EngineConfig(mode="streamed", channel=ChannelConfig(pipeline=True)),
                                stream_store=store,
                            ).run()

        ck = Checkpointer(str(tmp_path / "ck"), every=2)
        log = RunFileMessageLog(str(tmp_path / "logs"))
        eng = GraphDEngine(
                  pgs,
                  mk(),
                  config=EngineConfig(mode="streamed", channel=ChannelConfig(pipeline=True, fault=fault_point)),
                  stream_store=store,
                  message_log=log,
              )
        with pytest.raises(ChannelError):
            eng.run(checkpointer=ck)
        assert fault_point.fired
        assert ck.latest() == 2  # crash happened after the step-2 checkpoint
        # the torn superstep-2 inbox must NOT have published an index: a
        # partially transmitted step is unusable state, not a silent replay
        assert not os.path.exists(
            os.path.join(str(tmp_path / "logs"), "step-000002", "index.json")
        )

        # restart: resumes from the checkpoint, re-runs the torn superstep
        # from scratch (open_step truncates), finishes bit-identically
        eng2 = GraphDEngine(
                   pgs,
                   mk(),
                   config=EngineConfig(mode="streamed", channel=ChannelConfig(pipeline=True)),
                   stream_store=store,
                   message_log=RunFileMessageLog(str(tmp_path / "logs")),
               )
        (v2, a2), hist = eng2.run(checkpointer=ck)
        assert hist[0].step == 2 and hist[0].restored_from == 2
        assert np.array_equal(np.asarray(v2), np.asarray(v_ref))
        assert np.array_equal(np.asarray(a2), np.asarray(a_ref))

    @pytest.mark.parametrize("failed", [0, 3])
    def test_recover_shard_from_pipelined_logs(self, streamed_job, tmp_path,
                                               failed):
        """Single-shard fast recovery over CHANNEL-written logs: the inbox
        runs the background sender appended are the persisted OMSs of §3.4,
        and replaying them must land on the same state bit for bit."""
        _, pgs, _, store = streamed_job
        mk = lambda: PageRank(supersteps=6)
        ck = Checkpointer(str(tmp_path / "ck"), every=3)
        log = RunFileMessageLog(str(tmp_path / "logs"))
        eng = GraphDEngine(
                  pgs,
                  mk(),
                  config=EngineConfig(mode="streamed", channel=ChannelConfig(pipeline=True)),
                  stream_store=store,
                  message_log=log,
              )
        ck.save(0, *eng.init())
        (v_ref, a_ref), _ = eng.run(checkpointer=ck)
        vj, aj = recover_shard_streamed(
            pgs, mk(), failed=failed, ckpt=ck, log=log, store=store,
            target_step=6,
        )
        assert np.array_equal(np.asarray(vj), np.asarray(v_ref)[failed])
        assert np.array_equal(np.asarray(aj), np.asarray(a_ref)[failed])

    def test_sender_crash_combinerless_rerun_matches(self, streamed_job,
                                                     tmp_path):
        """Same drill on the OMS path: the sender dies while sorting/spilling
        raw message runs; a rerun over the truncated step store must
        bit-match an uninterrupted run."""
        _, pgs, _, store = streamed_job
        mk = lambda: DistinctInLabels(n_groups=8, rounds=3)
        (v_ref, a_ref), _ = GraphDEngine(
                                pgs,
                                mk(),
                                config=EngineConfig(mode="streamed", channel=ChannelConfig(pipeline=True)),
                                stream_store=store,
                            ).run()
        ck = Checkpointer(str(tmp_path / "ck"), every=1)
        log = RunFileMessageLog(str(tmp_path / "logs"))
        eng = GraphDEngine(
                  pgs,
                  mk(),
                  config=EngineConfig(mode="streamed", channel=ChannelConfig(pipeline=True, fault=FaultPoint(after_packets=20))),
                  stream_store=store,
                  message_log=log,
              )
        with pytest.raises(ChannelError):
            eng.run(checkpointer=ck)
        eng2 = GraphDEngine(
                   pgs,
                   mk(),
                   config=EngineConfig(mode="streamed", channel=ChannelConfig(pipeline=True)),
                   stream_store=store,
                   message_log=RunFileMessageLog(str(tmp_path / "logs")),
               )
        (v2, a2), _ = eng2.run(checkpointer=ck)
        assert np.array_equal(np.asarray(v2), np.asarray(v_ref))
        assert np.array_equal(np.asarray(a2), np.asarray(a_ref))

    def test_receiver_crash_surfaces_midstep_then_rerun_matches(
        self, streamed_job, tmp_path
    ):
        """Full-duplex drill: the RECEIVER thread dies mid-digest (after 40
        digested runs = run 8 of 16 of superstep 2, right after the step-2
        checkpoint landed). The error must surface on the compute thread,
        the torn inbox must stay unpublished, a rerun must bit-match an
        uninterrupted run, and single-shard fast recovery over the healthy
        rerun's logs must bit-match too (satellite: mid-digest kill →
        rerun + recover_shard_streamed bit-match)."""
        from repro.core import ChannelConfig, EngineConfig

        _, pgs, _, store = streamed_job
        mk = lambda: PageRank(supersteps=6)
        cfg = lambda **ch: EngineConfig(
            mode="streamed", channel=ChannelConfig(pipeline=True, **ch)
        )
        (v_ref, a_ref), _ = GraphDEngine(
            pgs, mk(), config=cfg(), stream_store=store
        ).run()

        ck = Checkpointer(str(tmp_path / "ck"), every=2)
        log = RunFileMessageLog(str(tmp_path / "logs"))
        recv_fault = FaultPoint(after_packets=40,
                                message="injected receiver fault")
        eng = GraphDEngine(pgs, mk(), config=cfg(recv_fault=recv_fault),
                           stream_store=store, message_log=log)
        with pytest.raises(ChannelError):
            eng.run(checkpointer=ck)
        assert recv_fault.fired
        assert ck.latest() == 2
        # the torn superstep-2 inbox must NOT have published an index
        assert not os.path.exists(
            os.path.join(str(tmp_path / "logs"), "step-000002", "index.json")
        )

        log2 = RunFileMessageLog(str(tmp_path / "logs"))
        eng2 = GraphDEngine(pgs, mk(), config=cfg(), stream_store=store,
                            message_log=log2)
        (v2, a2), hist = eng2.run(checkpointer=ck)
        assert hist[0].step == 2 and hist[0].restored_from == 2
        assert np.array_equal(np.asarray(v2), np.asarray(v_ref))
        assert np.array_equal(np.asarray(a2), np.asarray(a_ref))
        # the channel-written logs of the rerun replay bit-identically
        vj, aj = recover_shard_streamed(
            pgs, mk(), failed=1, ckpt=ck, log=log2, store=store,
            target_step=6,
        )
        assert np.array_equal(np.asarray(vj), np.asarray(v_ref)[1])
        assert np.array_equal(np.asarray(aj), np.asarray(a_ref)[1])

    def test_receiver_crash_combinerless_rerun_matches(self, streamed_job,
                                                       tmp_path):
        """Same drill on the OMS path: the receiver thread producing merged
        apply slices dies mid-merge; the superstep fails loudly and a rerun
        over the truncated step store bit-matches an uninterrupted run."""
        from repro.core import ChannelConfig, EngineConfig

        _, pgs, _, store = streamed_job
        mk = lambda: DistinctInLabels(n_groups=8, rounds=3)
        cfg = lambda **ch: EngineConfig(
            mode="streamed", channel=ChannelConfig(pipeline=True, **ch)
        )
        (v_ref, a_ref), _ = GraphDEngine(
            pgs, mk(), config=cfg(), stream_store=store
        ).run()
        ck = Checkpointer(str(tmp_path / "ck"), every=1)
        log = RunFileMessageLog(str(tmp_path / "logs"))
        eng = GraphDEngine(
            pgs, mk(),
            config=cfg(recv_fault=FaultPoint(
                after_packets=6, message="injected receiver fault")),
            stream_store=store, message_log=log,
        )
        with pytest.raises(ChannelError):
            eng.run(checkpointer=ck)
        eng2 = GraphDEngine(
            pgs, mk(), config=cfg(), stream_store=store,
            message_log=RunFileMessageLog(str(tmp_path / "logs")),
        )
        (v2, a2), _ = eng2.run(checkpointer=ck)
        assert np.array_equal(np.asarray(v2), np.asarray(v_ref))
        assert np.array_equal(np.asarray(a2), np.asarray(a_ref))

    def test_crash_without_log_leaves_no_scratch_leak(self, streamed_job,
                                                      tmp_path):
        """A sender crash with NO message log leaves the scratch inbox of
        the torn step behind; the next run on the same store must sweep it
        (like Checkpointer sweeps .tmp-step-*) and finish clean."""
        _, pgs, _, store = streamed_job
        eng = GraphDEngine(
                  pgs,
                  PageRank(supersteps=4),
                  config=EngineConfig(mode="streamed", channel=ChannelConfig(pipeline=True, fault=FaultPoint(after_packets=20))),
                  stream_store=store,
              )
        with pytest.raises(ChannelError):
            eng.run()
        inbox = os.path.join(store.dir, "inbox")
        leftovers = [n for n in os.listdir(inbox)
                     if n.startswith("step-")]
        assert leftovers  # the torn step really was left on disk
        GraphDEngine(
            pgs,
            PageRank(supersteps=4),
            config=EngineConfig(mode="streamed", channel=ChannelConfig(pipeline=True)),
            stream_store=store,
        ).run()
        assert [n for n in os.listdir(inbox) if n.startswith("step-")] == []


# -- whole-process crash drills (launch="processes") -------------------------

@pytest.fixture(scope="module")
def procs_graph():
    return rmat_graph(scale=6, edge_factor=6, seed=5, weights="uniform")


class TestProcessCrashDrill:
    """kill -9 a worker PROCESS mid-superstep: the coordinator detects the
    death, respawns just that shard with ``--recover-to``, the respawn
    replays forward from the latest checkpoint over its own message log,
    and the finished run is bit-identical to an undisturbed one."""

    def _plan(self, prog, g):
        from repro.core import MemoryBudget
        from repro.core.plan import GraphMeta, plan as make_plan

        return make_plan(prog, GraphMeta.of(g), MemoryBudget(n_shards=3),
                         launch="processes")

    def test_kill9_recovers_bit_identical(self, procs_graph, tmp_path):
        import copy

        from repro.core import GraphDJob

        g = procs_graph
        p = self._plan(HashMin(), g)
        ref = GraphDJob(HashMin(), g, plan=copy.deepcopy(p),
                        workdir=str(tmp_path / "ref"), checkpoint_every=2)
        r_ref = ref.run()
        drilled = GraphDJob(
            HashMin(), g, plan=copy.deepcopy(p),
            workdir=str(tmp_path / "drill"), checkpoint_every=2,
            launch="processes",
            # SIGKILL shard 1 mid-superstep 2: after its outbox for the
            # step is announced, before it applies/arrives
            launch_opts={"kill": {"shard": 1, "step": 2},
                         "heartbeat_timeout": 5.0},
        )
        r_drill = drilled.run()
        assert r_drill.n_supersteps == r_ref.n_supersteps
        assert [r.n_active for r in r_drill.history] == \
               [r.n_active for r in r_ref.history]
        assert [r.n_msgs for r in r_drill.history] == \
               [r.n_msgs for r in r_ref.history]
        assert r_drill.values == r_ref.values  # bit-identical after recovery
        # the drill really fired: exactly one respawn
        assert drilled._last_run_recoveries == 1
        ref.close()
        drilled.close()

    def test_kill9_mid_frame_socket_transport_recovers(self, procs_graph,
                                                       tmp_path):
        """The socket-transport drill: the victim SIGKILLs ITSELF with a
        run frame half-written on the wire (header + half payload). The
        peer's reader sees the torn frame, discards it, and waits; the
        respawned shard re-folds the step, the RESUME handshake replays
        from its outbox run-file log, duplicates are dropped by sequence,
        and the finished run is bit-identical to an undisturbed one."""
        import copy

        from repro.core import GraphDJob

        g = procs_graph
        p = self._plan(HashMin(), g)
        ref = GraphDJob(HashMin(), g, plan=copy.deepcopy(p),
                        workdir=str(tmp_path / "ref"), checkpoint_every=2)
        r_ref = ref.run()
        drilled = GraphDJob(
            HashMin(), g, plan=copy.deepcopy(p),
            workdir=str(tmp_path / "drill"), checkpoint_every=2,
            launch="processes",
            launch_opts={"transport": "sockets",
                         "kill_net": {"shard": 1, "step": 2,
                                      "after_frames": 1},
                         "heartbeat_timeout": 5.0},
        )
        r_drill = drilled.run()
        assert r_drill.n_supersteps == r_ref.n_supersteps
        assert [r.n_active for r in r_drill.history] == \
               [r.n_active for r in r_ref.history]
        assert [r.n_msgs for r in r_drill.history] == \
               [r.n_msgs for r in r_ref.history]
        assert r_drill.values == r_ref.values  # bit-identical after recovery
        assert drilled._last_run_recoveries == 1  # the drill really fired
        ref.close()
        drilled.close()

    def test_kill9_without_recovery_wiring_fails_loud(self, procs_graph,
                                                      tmp_path):
        import copy

        from repro.core import GraphDJob
        from repro.core.coordinator import WorkerFailed

        g = procs_graph
        p = self._plan(HashMin(), g)
        job = GraphDJob(
            HashMin(), g, plan=copy.deepcopy(p),
            workdir=str(tmp_path / "bare"), launch="processes",
            launch_opts={"kill": {"shard": 2, "step": 1},
                         "heartbeat_timeout": 5.0},
        )
        with pytest.raises(WorkerFailed, match="checkpoint"):
            job.run()
        job.close()


# -- chaos layer drills (repro.fault): coordinator kill -9, disk faults, ----
# -- silent bit-flips --------------------------------------------------------

from test_equivalence import ALGORITHMS, EDGE_BLOCK  # noqa: E402


class TestCoordinatorKillDrill:
    """kill -9 the COORDINATOR process mid-barrier (sockets transport):
    arrivals received, commit not yet in the WAL. The launcher respawns it
    with a bumped incarnation; the successor restores committed steps and
    peer addresses from its WAL, workers reconnect (re-reading the
    incarnation-stamped address file) and replay their pending arrivals,
    and the finished run is bit-identical to an undisturbed one — for
    EVERY algorithm in the equivalence matrix, the acceptance bar for the
    chaos layer."""

    @pytest.fixture(scope="class")
    def drill_graph(self):
        g = rmat_graph(scale=6, edge_factor=6, seed=5, weights="uniform")
        _, rmap = partition_graph(g, n_shards=3, edge_block=EDGE_BLOCK)
        return g, rmap

    def _plan(self, prog, g):
        from repro.core import MemoryBudget
        from repro.core.plan import GraphMeta, plan as make_plan

        return make_plan(prog, GraphMeta.of(g), MemoryBudget(n_shards=3),
                         edge_block=EDGE_BLOCK, launch="processes")

    @pytest.mark.parametrize("name,factory,exact", ALGORITHMS,
                             ids=[a[0] for a in ALGORITHMS])
    def test_kill9_coordinator_mid_barrier_recovers_bit_identical(
            self, drill_graph, tmp_path, name, factory, exact):
        import copy

        from repro.core import GraphDJob

        g, rmap = drill_graph
        p = self._plan(factory(g, rmap), g)
        ref = GraphDJob(factory(g, rmap), g, plan=copy.deepcopy(p),
                        workdir=str(tmp_path / "ref"))
        r_ref = ref.run(max_supersteps=60)
        # kill as late as the algorithm allows: step 1 proves the WAL
        # commit restore too; single-superstep programs (degreesum) get
        # killed inside their only barrier
        kill_step = 1 if r_ref.n_supersteps > 1 else 0
        drilled = GraphDJob(
            factory(g, rmap), g, plan=copy.deepcopy(p),
            workdir=str(tmp_path / "drill"), checkpoint_every=2,
            launch="processes",
            # SIGKILL the coordinator mid-barrier, after at least one
            # arrival is in (the commit never hits the WAL)
            launch_opts={"transport": "sockets",
                         "coord_kill": {"step": kill_step,
                                        "after_arrivals": 1},
                         "heartbeat_timeout": 5.0},
        )
        r_drill = drilled.run(max_supersteps=60)
        assert r_drill.n_supersteps == r_ref.n_supersteps, name
        for field in ("n_active", "n_msgs", "agg"):
            assert [getattr(x, field) for x in r_drill.history] == \
                   [getattr(x, field) for x in r_ref.history], (name, field)
        assert r_drill.values == r_ref.values, name  # bit-identical
        # the drill really fired: one coordinator respawn, zero worker
        # respawns — the workers rode out the outage on their retry policy
        assert drilled._last_run_coord_restarts == 1
        assert drilled._last_run_recoveries == 0
        ref.close()
        drilled.close()


class TestDiskFaultDrill:
    """Deterministic disk faults (``launch_opts["faults"]`` schedules)
    against the storage tiers. ENOSPC mid-spill without recovery wiring
    fails loud with a structured record naming the tier and leaves no torn
    outbox index; ENOSPC on the very first checkpoint dump recovers by
    replaying the whole prefix from the message log; a silent bit-flip in
    a spilled blob is caught by read-path CRC verification, quarantined,
    and replayed — bit-identically."""

    def _plan(self, prog, g):
        from repro.core import MemoryBudget
        from repro.core.plan import GraphMeta, plan as make_plan

        return make_plan(prog, GraphMeta.of(g), MemoryBudget(n_shards=3),
                         launch="processes")

    def test_enospc_mid_spill_fails_loud_no_torn_index(self, procs_graph,
                                                       tmp_path):
        import copy
        import json

        from repro.core import GraphDJob
        from repro.core.coordinator import WorkerFailed

        g = procs_graph
        p = self._plan(HashMin(), g)
        job = GraphDJob(
            HashMin(), g, plan=copy.deepcopy(p),
            workdir=str(tmp_path / "bare"), launch="processes",
            launch_opts={
                "heartbeat_timeout": 5.0,
                "faults": {"seed": 7, "events": [
                    {"site": "io.write.spill", "kind": "enospc",
                     "shard": 1, "step": 1, "where": "outbox/"}]},
            },
        )
        with pytest.raises(WorkerFailed, match="spill") as ei:
            job.run()
        # the dying worker classified itself: the record names the tier
        rec = ei.value.record
        assert rec is not None
        assert rec["kind"] == "disk-fault"
        assert rec["tier"] == "spill"
        assert rec["shard"] == 1
        procs_dir = job._dir("procs", job._tag)
        # no torn outbox index: the un-announced src dir was swept, so no
        # peer (nor a post-mortem) can ever read a half-written run table
        assert not os.path.exists(
            os.path.join(procs_dir, "outbox", "step-000001", "src-1"))
        assert not os.path.exists(
            os.path.join(procs_dir, "announce", "step-000001", "src-1.json"))
        # the run-level failure summary (the chaos-soak artifact) landed
        with open(os.path.join(procs_dir, "failure-summary.json")) as f:
            summary = json.load(f)
        assert summary["kind"] == "launch-failed"
        assert summary["record"]["tier"] == "spill"
        job.close()

    def test_enospc_first_checkpoint_recovers_bit_identical(self,
                                                            procs_graph,
                                                            tmp_path):
        import copy

        from repro.core import GraphDJob

        g = procs_graph
        p = self._plan(HashMin(), g)
        ref = GraphDJob(HashMin(), g, plan=copy.deepcopy(p),
                        workdir=str(tmp_path / "ref"), checkpoint_every=2)
        r_ref = ref.run()
        drilled = GraphDJob(
            HashMin(), g, plan=copy.deepcopy(p),
            workdir=str(tmp_path / "drill"), checkpoint_every=2,
            launch="processes",
            # ENOSPC on worker 2's shard dump for the FIRST checkpoint
            # (step 2): nothing is checkpointed yet, so the respawn must
            # replay the whole prefix from the log on the bootstrap state
            launch_opts={
                "heartbeat_timeout": 5.0,
                "faults": [{"site": "io.write.ckpt", "kind": "enospc",
                            "shard": 2, "step": 2}],
            },
        )
        r_drill = drilled.run()
        assert r_drill.n_supersteps == r_ref.n_supersteps
        assert [r.n_active for r in r_drill.history] == \
               [r.n_active for r in r_ref.history]
        assert [r.n_msgs for r in r_drill.history] == \
               [r.n_msgs for r in r_ref.history]
        assert r_drill.values == r_ref.values  # bit-identical after replay
        assert drilled._last_run_recoveries == 1  # the drill really fired
        # the faulted dump tore nothing: every checkpoint dir is final
        ckpt_dir = drilled.checkpointer.dir
        assert not [n for n in os.listdir(ckpt_dir)
                    if n.startswith(".tmp")]
        ref.close()
        drilled.close()

    def test_bitflip_in_spilled_blob_quarantined_and_replayed(self,
                                                              procs_graph,
                                                              tmp_path):
        import copy

        from repro.core import GraphDJob

        g = procs_graph
        p = self._plan(HashMin(), g)
        ref = GraphDJob(HashMin(), g, plan=copy.deepcopy(p),
                        workdir=str(tmp_path / "ref"), checkpoint_every=2)
        r_ref = ref.run()
        drilled = GraphDJob(
            HashMin(), g, plan=copy.deepcopy(p),
            workdir=str(tmp_path / "drill"), checkpoint_every=2,
            launch="processes",
            # flip ONE bit in shard 1's message-log copy at step 1; the
            # write itself succeeds silently (the CRC is computed from the
            # pristine bytes), and the same step's digest reads it back
            launch_opts={
                "heartbeat_timeout": 5.0,
                "faults": {"seed": 41, "events": [
                    {"site": "io.write.spill", "kind": "bitflip",
                     "shard": 1, "step": 1, "where": "logs/"}]},
            },
        )
        r_drill = drilled.run()
        assert r_drill.n_supersteps == r_ref.n_supersteps
        assert [r.n_active for r in r_drill.history] == \
               [r.n_active for r in r_ref.history]
        assert r_drill.values == r_ref.values  # bit-identical after replay
        assert drilled._last_run_recoveries == 1  # detection really fired
        # the poisoned store is out of the lineage but kept for post-mortem
        q = os.path.join(drilled._dir("logs", drilled._tag), "shard-1",
                         "step-000001.quarantine")
        assert os.path.isdir(q)
        ref.close()
        drilled.close()
