"""Engine end-to-end tests: algorithms vs oracles, mode/backend equivalence,
sparse adaptation, shard-count invariance, Pregel semantics."""

import collections

import networkx as nx
import numpy as np
import pytest

from repro.core import (
    BFS, SSSP, DegreeSum, EngineConfig, GraphDEngine, HashMin, LabelSpread,
    PageRank,
)
from repro.graph import chain_graph, erdos_renyi_graph, partition_graph, rmat_graph


def _nx_digraph(g):
    G = nx.DiGraph()
    G.add_nodes_from(g.vertex_ids.tolist())
    G.add_weighted_edges_from(
        zip(g.src.tolist(), g.dst.tolist(), g.weight.tolist())
    )
    return G


def _pagerank_oracle(g, iters, damping=0.85):
    """The paper's §2.1 update rule (lost mass at dangling vertices)."""
    ids = {int(o): i for i, o in enumerate(sorted(g.vertex_ids.tolist()))}
    V = g.n_vertices
    out = collections.defaultdict(list)
    deg = collections.Counter()
    for s, d in zip(g.src.tolist(), g.dst.tolist()):
        out[ids[s]].append(ids[d])
        deg[ids[s]] += 1
    a = np.full(V, 1.0 / V)
    for _ in range(iters):
        nxt = np.full(V, 0.15 / V)
        for u, nbrs in out.items():
            share = damping * a[u] / deg[u]
            for v in nbrs:
                nxt[v] += share
        a = nxt
    return {int(o): a[ids[int(o)]] for o in g.vertex_ids}


class TestPageRank:
    @pytest.mark.parametrize("n", [1, 2, 4, 7])
    def test_vs_oracle(self, n):
        g = rmat_graph(scale=7, edge_factor=8, seed=3)
        pg, _ = partition_graph(g, n_shards=n, edge_block=64)
        eng = GraphDEngine(pg, PageRank(supersteps=10))
        (vals, _), hist = eng.run()
        got = eng.gather_values(vals)
        want = _pagerank_oracle(g, 10)
        err = max(abs(got[k] - want[k]) for k in want)
        assert err < 1e-5
        assert len(hist) == 10

    def test_shard_count_invariance(self):
        g = rmat_graph(scale=7, edge_factor=6, seed=11)
        ref = None
        for n in [1, 2, 4, 8]:
            pg, _ = partition_graph(g, n_shards=n, edge_block=32)
            eng = GraphDEngine(pg, PageRank(supersteps=6))
            (vals, _), _ = eng.run()
            got = eng.gather_values(vals)
            if ref is None:
                ref = got
            else:
                assert max(abs(got[k] - ref[k]) for k in ref) < 1e-6

    def test_aggregator_monotone_convergence(self):
        g = rmat_graph(scale=7, edge_factor=8, seed=3)
        pg, _ = partition_graph(g, n_shards=4, edge_block=64)
        (_, _), hist = GraphDEngine(pg, PageRank(supersteps=12)).run()
        # L1 delta aggregator decreases (power iteration contracts)
        aggs = [h.agg for h in hist[2:]]
        assert all(b <= a * 1.01 for a, b in zip(aggs, aggs[1:]))


class TestModesAndBackends:
    """IO-Basic (raw + merge-sort) == IO-Basic w/ sender combine == IO-Recoded
    == Pallas-kernel IO-Recoded (Tables 2–8 rows must agree on results)."""

    @pytest.mark.parametrize("mode", ["basic", "basic_sc"])
    def test_mode_equivalence(self, mode):
        g = rmat_graph(scale=7, edge_factor=8, seed=3)
        pg, _ = partition_graph(g, n_shards=4, edge_block=64)
        (v_ref, _), _ = GraphDEngine(
                            pg,
                            PageRank(supersteps=5),
                            config=EngineConfig(mode="recoded"),
                        ).run()
        (v, _), _ = GraphDEngine(
                        pg,
                        PageRank(supersteps=5),
                        config=EngineConfig(mode=mode),
                    ).run()
        assert np.abs(np.asarray(v) - np.asarray(v_ref)).max() < 1e-6

    @pytest.mark.parametrize(
        "prog_f",
        [lambda: PageRank(supersteps=5), lambda: HashMin(),
         lambda: DegreeSum()],
        ids=["pagerank", "hashmin", "degreesum"],
    )
    def test_pallas_backend(self, prog_f):
        g = rmat_graph(scale=7, edge_factor=8, seed=3)
        pg, _ = partition_graph(g, n_shards=4, edge_block=64, vertex_pad=32)
        (vj, _), _ = GraphDEngine(
                         pg,
                         prog_f(),
                         config=EngineConfig(backend="jnp"),
                     ).run()
        (vp, _), _ = GraphDEngine(
                         pg,
                         prog_f(),
                         config=EngineConfig(backend="pallas", kernel_windows=32),
                     ).run()
        err = np.abs(
            np.asarray(vj).astype(np.float64)
            - np.asarray(vp).astype(np.float64)
        ).max()
        assert err < 1e-5

    def test_pallas_sssp_with_inf(self):
        g = rmat_graph(scale=7, edge_factor=4, seed=13)  # leaves unreachables
        pg, rmap = partition_graph(g, n_shards=4, edge_block=64, vertex_pad=32)
        src_new = int(rmap.to_new(np.array([int(g.vertex_ids[0])]))[0])
        (vj, _), _ = GraphDEngine(
                         pg,
                         SSSP(src_new),
                         config=EngineConfig(backend="jnp"),
                     ).run()
        (vp, _), _ = GraphDEngine(
                         pg,
                         SSSP(src_new),
                         config=EngineConfig(backend="pallas", kernel_windows=32),
                     ).run()
        vj_, vp_ = np.asarray(vj), np.asarray(vp)
        # unreached: jnp=inf, pallas=large-finite sentinel; reached: equal
        assert ((vj_ == vp_) | (np.isinf(vj_) & (vp_ >= 1e29))).all()


class TestMessageListPath:
    """Non-combiner Pregel (paper §3.3): destination-sorted message lists."""

    def test_distinct_in_labels_vs_oracle(self):
        from repro.core.algorithms import DistinctInLabels

        g = rmat_graph(scale=7, edge_factor=6, seed=9)
        pg, rmap = partition_graph(g, n_shards=4, edge_block=32)
        eng = GraphDEngine(
                  pg,
                  DistinctInLabels(n_groups=5),
                  config=EngineConfig(mode="basic"),
              )
        (vals, _), hist = eng.run()
        got = eng.gather_values(vals)
        src_new, dst_new = rmap.to_new(g.src), rmap.to_new(g.dst)
        lab = {int(gid): int(gid) % 5 for gid in rmap.new_for_old_sorted}
        want = collections.defaultdict(set)
        for s, d in zip(src_new.tolist(), dst_new.tolist()):
            want[d].add(lab[s])
        for old, v in got.items():
            gid = int(rmap.to_new(np.array([old]))[0])
            assert v == len(want.get(gid, set()))

    def test_rejects_recoded_mode(self):
        from repro.core.algorithms import DistinctInLabels

        g = rmat_graph(scale=6, edge_factor=4, seed=1)
        pg, _ = partition_graph(g, n_shards=2, edge_block=32)
        with pytest.raises(ValueError, match="combiner"):
            GraphDEngine(
                pg,
                DistinctInLabels(),
                config=EngineConfig(mode="recoded"),
            )


class TestTopologyMutation:
    """Paper §3.4: edge/vertex mutation between supersteps."""

    def test_add_remove_and_continue(self):
        from repro.core.mutation import mutate

        g = rmat_graph(scale=7, edge_factor=6, seed=9)
        pg0, _ = partition_graph(g, n_shards=4, edge_block=32)
        eng0 = GraphDEngine(pg0, PageRank(supersteps=4))
        (v0, a0), _ = eng0.run(max_supersteps=2)
        pg1, v1, a1, new_g = mutate(pg0, v0, a0, add_vertices=3)
        assert pg1.n_vertices == pg0.n_vertices + 3
        e_add = [(int(new_g[0]), int(new_g[1])),
                 (int(new_g[1]), int(new_g[2]))]
        pg2, v2, a2, _ = mutate(pg1, v1, a1, add_edges=e_add)
        assert pg2.n_edges == pg1.n_edges + 2
        eng1 = GraphDEngine(pg2, PageRank(supersteps=4))
        (v3, _), _ = eng1.run(state=(v2, a2), start_step=2)
        assert np.isfinite(np.asarray(v3)).all()
        pg3, _, _, _ = mutate(pg2, v3, a2, remove_edges=e_add)
        assert pg3.n_edges == pg2.n_edges - 2

    def test_positions_stable_under_mutation(self):
        from repro.core.mutation import mutate

        g = rmat_graph(scale=6, edge_factor=4, seed=2)
        pg0, _ = partition_graph(g, n_shards=4, edge_block=32)
        eng = GraphDEngine(pg0, PageRank(supersteps=2))
        (v0, a0), _ = eng.run()
        pg1, v1, _, _ = mutate(pg0, v0, a0, add_vertices=5)
        g0 = np.asarray(pg0.gids)[np.asarray(pg0.vmask)]
        # every pre-existing gid keeps its (shard, pos) and value
        old_vals = np.asarray(v0)
        new_vals = np.asarray(v1)
        for gid in g0[:50]:
            s, p = int(gid) % 4, int(gid) // 4
            assert old_vals[s, p] == new_vals[s, p]


class TestCompactWire:
    """§Perf beyond-paper variant: bf16+bool one-hop exchange."""

    def test_pagerank_tolerance(self):
        g = rmat_graph(scale=8, edge_factor=8, seed=3)
        pg, _ = partition_graph(g, n_shards=4, edge_block=64)
        (v1, _), _ = GraphDEngine(
                         pg,
                         PageRank(supersteps=10),
                         config=EngineConfig(mode="recoded"),
                     ).run()
        (v2, _), _ = GraphDEngine(
                         pg,
                         PageRank(supersteps=10),
                         config=EngineConfig(mode="recoded_compact"),
                     ).run()
        a, b = np.asarray(v1), np.asarray(v2)
        rel = np.abs(a - b) / np.maximum(np.abs(a), 1e-9)
        assert rel.max() < 2e-2  # one bf16 rounding per message

    def test_rejects_int_messages(self):
        g = rmat_graph(scale=6, edge_factor=4, seed=1)
        pg, _ = partition_graph(g, n_shards=2, edge_block=32)
        with pytest.raises(ValueError, match="float messages"):
            GraphDEngine(
                pg,
                HashMin(),
                config=EngineConfig(mode="recoded_compact"),
            )


class TestFlatHeadAttention:
    """§Perf variant: repeated-KV flat heads == grouped GQA numerics."""

    def test_forward_equivalence(self):
        import jax
        from repro.configs import ARCHS
        from repro.data.tokens import synthetic_batch
        from repro.models.attention import set_flat_heads
        from repro.models.transformer import forward, init_params

        cfg = ARCHS["minitron-4b"].reduced()
        params = init_params(cfg, jax.random.key(0))
        batch = synthetic_batch(cfg, 0, 32, 2)
        l1, _ = jax.jit(lambda p, t: forward(cfg, p, t))(
            params, batch["tokens"]
        )
        set_flat_heads(True)
        try:
            l2, _ = jax.jit(lambda p, t: forward(cfg, p, t))(
                params, batch["tokens"]
            )
        finally:
            set_flat_heads(False)
        assert np.abs(np.asarray(l1) - np.asarray(l2)).max() < 1e-2


class TestSSSPAndBFS:
    def test_bfs_vs_networkx(self):
        g = rmat_graph(scale=7, edge_factor=8, seed=3)
        pg, rmap = partition_graph(g, n_shards=4, edge_block=64)
        G = _nx_digraph(g)
        src_old = int(g.vertex_ids[0])
        src_new = int(rmap.to_new(np.array([src_old]))[0])
        eng = GraphDEngine(pg, BFS(src_new))
        (vals, _), _ = eng.run()
        got = eng.gather_values(vals)
        want = nx.single_source_shortest_path_length(G, src_old)
        for k, v in got.items():
            w = want.get(k, np.inf)
            assert v == w or (np.isinf(v) and np.isinf(w))

    def test_weighted_sssp_vs_networkx(self):
        g = rmat_graph(scale=7, edge_factor=8, seed=5, weights="uniform")
        pg, rmap = partition_graph(g, n_shards=3, edge_block=64)
        G = _nx_digraph(g)
        src_old = int(g.vertex_ids[1])
        src_new = int(rmap.to_new(np.array([src_old]))[0])
        eng = GraphDEngine(pg, SSSP(src_new))
        (vals, _), _ = eng.run()
        got = eng.gather_values(vals)
        want = nx.single_source_dijkstra_path_length(G, src_old)
        for k, v in got.items():
            w = want.get(k, np.inf)
            assert (np.isinf(v) and np.isinf(w)) or abs(v - w) < 1e-4

    def test_chain_sparse_adaptation(self):
        """skip() engages on the pathological 1-vertex frontier (paper §6's
        'graphs whose structure requires a large number of iterations')."""
        g = chain_graph(256)
        pg, rmap = partition_graph(g, n_shards=4, edge_block=16)
        src_new = int(rmap.to_new(np.array([0]))[0])
        eng = GraphDEngine(
                  pg,
                  SSSP(src_new),
                  config=EngineConfig(adapt_threshold=0.5, sparse_cap_frac=0.5),
              )
        (vals, _), hist = eng.run(max_supersteps=300)
        modes = collections.Counter(h.mode for h in hist)
        assert modes["sparse"] > modes["dense"]
        got = eng.gather_values(vals)
        assert all(got[k] == k for k in got)  # dist(0→k) = k on the chain

    def test_sparse_equals_dense(self):
        g = rmat_graph(scale=8, edge_factor=4, seed=21)
        pg, rmap = partition_graph(g, n_shards=4, edge_block=32)
        src_new = int(rmap.to_new(np.array([int(g.vertex_ids[0])]))[0])
        (vd, _), _ = GraphDEngine(
                         pg,
                         SSSP(src_new),
                         config=EngineConfig(adapt_threshold=-1),
                     ).run()
        (vs, _), hs = GraphDEngine(
                          pg,
                          SSSP(src_new),
                          config=EngineConfig(adapt_threshold=0.9, sparse_cap_frac=0.9),
                      ).run()
        assert np.array_equal(np.asarray(vd), np.asarray(vs))
        assert any(h.mode == "sparse" for h in hs)


class TestHashMin:
    @pytest.mark.parametrize("n", [1, 4])
    def test_components_vs_networkx(self, n):
        g = erdos_renyi_graph(400, 1.2, seed=5, directed=False)
        pg, _ = partition_graph(g, n_shards=n, edge_block=32)
        eng = GraphDEngine(pg, HashMin())
        (vals, _), _ = eng.run()
        got = eng.gather_values(vals)
        G = nx.Graph()
        G.add_nodes_from(g.vertex_ids.tolist())
        G.add_edges_from(zip(g.src.tolist(), g.dst.tolist()))
        comps = list(nx.connected_components(G))
        labels = [frozenset(got[v] for v in c) for c in comps]
        assert all(len(l) == 1 for l in labels)  # one label per component
        assert len(set(labels)) == len(comps)  # distinct across components

    def test_labelspread_max_dual(self):
        g = erdos_renyi_graph(200, 1.5, seed=6, directed=False)
        pg, _ = partition_graph(g, n_shards=3, edge_block=32)
        eng = GraphDEngine(pg, LabelSpread())
        (vals, _), _ = eng.run()
        got = eng.gather_values(vals)
        G = nx.Graph()
        G.add_nodes_from(g.vertex_ids.tolist())
        G.add_edges_from(zip(g.src.tolist(), g.dst.tolist()))
        for c in nx.connected_components(G):
            assert len({got[v] for v in c}) == 1


class TestPregelSemantics:
    def test_degree_sum_one_superstep(self):
        g = rmat_graph(scale=6, edge_factor=6, seed=8)
        pg, _ = partition_graph(g, n_shards=4, edge_block=32)
        eng = GraphDEngine(pg, DegreeSum())
        (vals, active), hist = eng.run()
        assert len(hist) == 1
        assert int(hist[0].n_active) == 0  # everyone voted to halt
        # oracle: sum of in-neighbours' out-degrees
        got = eng.gather_values(vals)
        deg = collections.Counter(g.src.tolist())
        want = collections.defaultdict(float)
        for s, d in zip(g.src.tolist(), g.dst.tolist()):
            want[d] += deg[s]
        for k, v in got.items():
            assert abs(v - want.get(k, 0.0)) < 1e-4

    def test_message_conservation(self):
        """Every generated message is digested exactly once: n_msgs == number
        of edges out of active vertices each superstep."""
        g = rmat_graph(scale=6, edge_factor=6, seed=9)
        pg, _ = partition_graph(g, n_shards=4, edge_block=32)
        eng = GraphDEngine(pg, PageRank(supersteps=3))
        (_, _), hist = eng.run()
        for h in hist:
            assert h.n_msgs == g.n_edges  # all vertices active in PageRank

    def test_quiescence_termination(self):
        g = chain_graph(32)
        pg, rmap = partition_graph(g, n_shards=2, edge_block=8)
        src_new = int(rmap.to_new(np.array([31]))[0])  # sink: no out-edges
        eng = GraphDEngine(pg, SSSP(src_new))
        (_, _), hist = eng.run()
        assert len(hist) == 1  # immediately quiescent


# NOTE: hypothesis-based property tests (mode agreement on random graphs,
# recode bijections, kernel-vs-oracle sweeps) live in test_properties.py,
# which skips cleanly when `hypothesis` is not installed (see conftest.py).
