"""Distributed (shard_map) execution tests.

These spawn subprocesses with XLA_FLAGS=--xla_force_host_platform_device_count
so the main pytest process keeps exactly 1 device (dry-run isolation rule).
"""

import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        env=env, capture_output=True, text=True, timeout=900,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


def test_shard_map_equals_vmap_all_modes():
    out = _run("""
        import jax, numpy as np
        from repro.graph import rmat_graph, partition_graph
        from repro.core import EngineConfig, GraphDEngine, PageRank
        g = rmat_graph(scale=8, edge_factor=8, seed=3)
        pg, _ = partition_graph(g, n_shards=8, edge_block=64)
        mesh = jax.make_mesh((8,), ('machines',))
        for mode in ['recoded', 'basic', 'basic_sc']:
            (v_sm, _), _ = GraphDEngine(
                               pg,
                               PageRank(supersteps=5),
                               config=EngineConfig(mode=mode),
                               mesh=mesh,
                           ).run()
            (v_vm, _), _ = GraphDEngine(
                               pg,
                               PageRank(supersteps=5),
                               config=EngineConfig(mode=mode),
                               mesh=None,
                           ).run()
            err = np.abs(np.asarray(v_sm) - np.asarray(v_vm)).max()
            assert err < 1e-7, (mode, err)
        print('OK')
    """)
    assert "OK" in out


def test_shard_map_sparse_sssp():
    out = _run("""
        import jax, numpy as np, collections
        from repro.graph import rmat_graph, partition_graph
        from repro.core import EngineConfig, GraphDEngine, SSSP
        g = rmat_graph(scale=8, edge_factor=8, seed=3)
        pg, rmap = partition_graph(g, n_shards=8, edge_block=64)
        mesh = jax.make_mesh((8,), ('machines',))
        src = int(rmap.to_new(np.array([int(g.vertex_ids[0])]))[0])
        es = GraphDEngine(
                 pg,
                 SSSP(src),
                 config=EngineConfig(adapt_threshold=0.6, sparse_cap_frac=0.6),
                 mesh=mesh,
             )
        (vs, _), hs = es.run()
        ev = GraphDEngine(
                 pg,
                 SSSP(src),
                 config=EngineConfig(adapt_threshold=-1),
                 mesh=None,
             )
        (vv, _), _ = ev.run()
        assert np.array_equal(np.asarray(vs), np.asarray(vv))
        modes = collections.Counter(h.mode for h in hs)
        print('OK', dict(modes))
    """)
    assert "OK" in out


def test_shard_map_pallas_backend():
    out = _run("""
        import jax, numpy as np
        from repro.graph import rmat_graph, partition_graph
        from repro.core import EngineConfig, GraphDEngine, PageRank
        g = rmat_graph(scale=8, edge_factor=8, seed=3)
        pg, _ = partition_graph(g, n_shards=4, edge_block=64, vertex_pad=32)
        mesh = jax.make_mesh((4,), ('machines',))
        (vp, _), _ = GraphDEngine(
                         pg,
                         PageRank(supersteps=4),
                         config=EngineConfig(backend='pallas', kernel_windows=32),
                         mesh=mesh,
                     ).run()
        (vj, _), _ = GraphDEngine(
                         pg,
                         PageRank(supersteps=4),
                         config=EngineConfig(backend='jnp'),
                     ).run()
        err = np.abs(np.asarray(vp) - np.asarray(vj)).max()
        assert err < 1e-6, err
        print('OK')
    """, devices=4)
    assert "OK" in out


def test_logged_mode_shard_map_and_recovery():
    out = _run("""
        import jax, numpy as np, tempfile, os
        from repro.graph import rmat_graph, partition_graph
        from repro.core import EngineConfig, GraphDEngine, PageRank
        from repro.core.checkpoint import Checkpointer, MessageLog, recover_shard
        g = rmat_graph(scale=7, edge_factor=8, seed=3)
        pg, _ = partition_graph(g, n_shards=4, edge_block=64)
        mesh = jax.make_mesh((4,), ('machines',))
        prog = PageRank(supersteps=6)
        (v_ref, _), _ = GraphDEngine(pg, prog).run()
        with tempfile.TemporaryDirectory() as d:
            ck = Checkpointer(os.path.join(d, 'c'), every=2)
            ml = MessageLog(os.path.join(d, 'l'))
            eng = GraphDEngine(pg, prog, mesh=mesh, message_log=ml)
            ck.save(0, *eng.init())
            (v, _), _ = eng.run(checkpointer=ck)
            assert np.allclose(np.asarray(v), np.asarray(v_ref))
            vj, _ = recover_shard(pg, prog, failed=3, ckpt=ck, log=ml,
                                  target_step=6)
            assert np.abs(np.asarray(vj) - np.asarray(v_ref)[3]).max() < 1e-6
        print('OK')
    """, devices=4)
    assert "OK" in out


def test_sharded_train_step_matches_single_device():
    """FSDP+TP train step on a (2,4) mesh == single-device numerics."""
    out = _run("""
        import jax, numpy as np, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.data.tokens import synthetic_batch
        from repro.models.transformer import init_params
        from repro.models import sharding as shd
        from repro.launch.mesh import batch_specs_tree, param_specs, to_shardings
        from repro.training.optimizer import AdamWConfig
        from repro.training.train import init_train_state, make_train_step

        cfg = get_config('minitron-4b').reduced()
        params = init_params(cfg, jax.random.key(0))
        opt = init_train_state(cfg, params)
        batch = synthetic_batch(cfg, 0, 32, 8)
        ref_step = jax.jit(make_train_step(cfg, AdamWConfig(total_steps=10)))
        p1, o1, m1 = ref_step(params, opt, batch)

        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        ps = param_specs(params, mesh)
        os_ = dict(mu=ps, nu=ps, step=P())
        bs = batch_specs_tree(batch, mesh)
        with mesh, shd.rules(batch='data', model='model', mesh=mesh):
            fn = jax.jit(
                make_train_step(cfg, AdamWConfig(total_steps=10)),
                in_shardings=to_shardings((ps, os_, bs), mesh),
            )
            p2, o2, m2 = fn(params, opt, batch)
        assert abs(float(m1['loss']) - float(m2['loss'])) < 1e-3
        d = max(float(jnp.abs(a.astype(jnp.float32) -
                              b.astype(jnp.float32)).max())
                for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
        assert d < 1e-2, d
        print('OK', float(m1['loss']), float(m2['loss']))
    """)
    assert "OK" in out


def test_graphd_dryrun_small_mesh():
    """The GraphD dry-run path lowers+compiles on a small flat ring."""
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.compat import shard_map
        from repro.core.algorithms import PageRank
        from repro.core.engine import superstep_spmd
        from repro.graph.partition import abstract_partitioned_graph

        n = 8
        mesh = Mesh(np.asarray(jax.devices()[:n]), ('machines',))
        pg = abstract_partitioned_graph(n, 1_000_000, 16_000_000,
                                        edge_block=1024, vertex_pad=128)
        prog = PageRank(supersteps=3)

        def step(pg_, v, a, s):
            sq = lambda t: jax.tree.map(lambda x: x[0], t)
            nv, na, st = superstep_spmd(prog, sq(pg_), sq(v), sq(a), s,
                                        axis='machines', mode='recoded')
            return nv[None], na[None], st

        spec = P('machines')
        fn = shard_map(step, mesh=mesh,
                       in_specs=(spec, spec, spec, P()),
                       out_specs=(spec, spec, P()))
        vals = jax.ShapeDtypeStruct((n, pg.P), jnp.float32)
        act = jax.ShapeDtypeStruct((n, pg.P), jnp.bool_)
        stp = jax.ShapeDtypeStruct((), jnp.int32)
        sh = NamedSharding(mesh, spec)
        compiled = jax.jit(
            fn, in_shardings=(jax.tree.map(lambda _: sh, pg), sh, sh,
                              NamedSharding(mesh, P())),
        ).lower(pg, vals, act, stp).compile()
        from repro.compat import cost_analysis
        cost = cost_analysis(compiled)
        assert cost.get('flops', 0) > 0
        print('OK', cost.get('flops'))
    """)
    assert "OK" in out


def test_ring_vs_alltoall_collective_equivalence():
    """The ring reduce-scatter (recoded) and the all_to_all (logged) paths
    produce identical digests — the collective schedule is semantically
    transparent."""
    out = _run("""
        import jax, numpy as np, tempfile, os
        from repro.graph import rmat_graph, partition_graph
        from repro.core import GraphDEngine, HashMin
        from repro.core.checkpoint import MessageLog
        g = rmat_graph(scale=7, edge_factor=6, seed=5, directed=False)
        pg, _ = partition_graph(g, n_shards=8, edge_block=32)
        mesh = jax.make_mesh((8,), ('machines',))
        (v1, _), _ = GraphDEngine(pg, HashMin(), mesh=mesh).run()
        with tempfile.TemporaryDirectory() as d:
            ml = MessageLog(os.path.join(d, 'l'))
            (v2, _), _ = GraphDEngine(pg, HashMin(), mesh=mesh,
                                      message_log=ml).run()
        assert np.array_equal(np.asarray(v1), np.asarray(v2))
        print('OK')
    """)
    assert "OK" in out
