"""Hypothesis property tests (recoding bijections, mode agreement, combiner
algebra, kernel-vs-oracle sweeps).

This module is the repo's only consumer of `hypothesis`; conftest.py skips it
cleanly when the package is absent so the tier-1 command stays green on a
bare interpreter. Fixed-seed versions of the load-bearing checks live in the
regular test modules and always run.
"""

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (see requirements-dev.txt)"
)

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import GraphDEngine, HashMin
from repro.core.api import IMAX, IMIN, MAX, MIN, OR, SUM
from repro.graph import Graph, partition_graph, recode_ids
from repro.graph.recode import recode_distributed


def edge_strategy(max_v=200, max_e=400):
    return st.lists(
        st.tuples(st.integers(0, max_v - 1), st.integers(0, max_v - 1)),
        min_size=1, max_size=max_e,
    )


# ---------------------------------------------------------------------------
# recoding (graph substrate)
# ---------------------------------------------------------------------------

@given(edge_strategy(), st.integers(1, 9))
@settings(max_examples=30, deadline=None)
def test_recode_bijection(edges, n):
    ids = np.unique(np.array([v for e in edges for v in e], dtype=np.int64))
    rmap = recode_ids(ids, n)
    new = rmap.to_new(ids)
    assert len(set(new.tolist())) == len(ids)
    assert np.array_equal(rmap.to_old(new), ids)
    for g in new:
        assert 0 <= g < n * rmap.max_positions


@given(edge_strategy(), st.integers(1, 6))
@settings(max_examples=20, deadline=None)
def test_distributed_recoding_matches_fast_path(edges, n):
    """Paper §5: the 3-superstep recoding job produces the same streams."""
    src = np.array([e[0] for e in edges], dtype=np.int64)
    dst = np.array([e[1] for e in edges], dtype=np.int64)
    ids = np.unique(np.concatenate([src, dst]))
    s1, d1, rmap = recode_distributed(src, dst, ids, n)
    assert np.array_equal(s1, rmap.to_new(src))
    assert np.array_equal(d1, rmap.to_new(dst))


@given(st.integers(2, 12))
@settings(max_examples=10, deadline=None)
def test_balance_random_ids(n):
    rng = np.random.default_rng(n)
    ids = np.unique(rng.integers(0, 2**48, size=5000))
    rmap = recode_ids(ids, n)
    assert rmap.max_positions < 2 * len(ids) / n


# ---------------------------------------------------------------------------
# combiner algebra (paper §2.1/§5: commutative, associative, identity e0)
# ---------------------------------------------------------------------------

_COMBINERS = {"sum": SUM, "min": MIN, "max": MAX, "or": OR,
              "imin": IMIN, "imax": IMAX}


def _domain(name, draw_ints):
    # OR operates on the boolean semiring; int combiners on int32.
    if name == "or":
        return np.array(draw_ints, dtype=np.int32) % 2
    return np.array(draw_ints, dtype=np.int32)


@pytest.mark.parametrize("name", list(_COMBINERS))
@given(st.lists(st.integers(-1000, 1000), min_size=3, max_size=3))
@settings(max_examples=25, deadline=None)
def test_combiner_associative_commutative_identity(name, vals):
    import jax.numpy as jnp

    comb = _COMBINERS[name]
    a, b, c = (jnp.asarray(v) for v in _domain(name, vals))
    as_bool = name == "or"
    norm = (lambda x: np.asarray(x).astype(bool)) if as_bool else np.asarray
    # commutative / associative
    assert norm(comb.combine(a, b)) == norm(comb.combine(b, a))
    assert norm(comb.combine(comb.combine(a, b), c)) == norm(
        comb.combine(a, comb.combine(b, c))
    )
    # e0 is a true identity
    dtype = jnp.int32 if name in ("or", "imin", "imax") else jnp.float32
    e0 = jnp.asarray(comb.e0, dtype)
    av = a.astype(dtype)
    assert norm(comb.combine(av, e0)) == norm(av)
    assert norm(comb.combine(e0, av)) == norm(av)


@pytest.mark.parametrize("name", ["sum", "min", "max", "or"])
@given(
    st.lists(st.tuples(st.integers(0, 15), st.integers(0, 50)),
             min_size=1, max_size=64),
)
@settings(max_examples=25, deadline=None)
def test_combiner_scatter_reduce_agree(name, pairs):
    """The scatter path (A_s in-memory combine) and the reduce path (stacked
    buffer fold) must realize the same abstract combine."""
    import jax.numpy as jnp

    comb = _COMBINERS[name]
    P = 16
    idx = np.array([p[0] for p in pairs], dtype=np.int32)
    msgs = _domain(name, [p[1] for p in pairs]).astype(np.float32)
    scattered = comb.scatter(
        comb.identity((P,), jnp.float32), jnp.asarray(idx), jnp.asarray(msgs)
    )
    # reduce path: one stacked one-slot buffer per message
    stack = np.full((len(pairs), P), float(comb.e0), dtype=np.float32)
    stack[np.arange(len(pairs)), idx] = msgs
    reduced = comb.reduce(jnp.asarray(stack), 0)
    sa, ra = np.asarray(scattered), np.asarray(reduced)
    if name == "or":
        np.testing.assert_array_equal(sa.astype(bool), ra.astype(bool))
    else:
        np.testing.assert_allclose(sa, ra, rtol=1e-6)


# ---------------------------------------------------------------------------
# engine: all exchange modes agree on random graphs
# ---------------------------------------------------------------------------

@given(
    st.lists(st.tuples(st.integers(0, 60), st.integers(0, 60)),
             min_size=1, max_size=150),
    st.integers(1, 5),
)
@settings(max_examples=15, deadline=None)
def test_property_modes_agree_on_random_graphs(edges, n):
    """Property: all exchange modes compute identical HashMin fixpoints."""
    src = np.array([e[0] for e in edges], dtype=np.int64)
    dst = np.array([e[1] for e in edges], dtype=np.int64)
    keep = src != dst
    if not keep.any():
        return
    g = Graph(src=src[keep], dst=dst[keep], weight=None, directed=False)
    pg, _ = partition_graph(g, n_shards=n, edge_block=8)
    outs = []
    for mode in ["recoded", "basic", "basic_sc"]:
        eng = GraphDEngine(pg, HashMin(), mode=mode)
        (vals, _), _ = eng.run()
        outs.append(eng.gather_values(vals))
    assert outs[0] == outs[1] == outs[2]


# ---------------------------------------------------------------------------
# kernels: Pallas vs oracle on random graphs × random frontiers
# ---------------------------------------------------------------------------

@given(st.integers(0, 2**31 - 1), st.floats(0.0, 1.0))
@settings(max_examples=10, deadline=None)
def test_property_kernel_matches_ref(seed, density):
    import jax.numpy as jnp

    from repro.graph import rmat_graph
    from repro.graph.kblocks import build_kernel_layout
    from repro.kernels import ops
    from repro.kernels.ref import edge_combine_ref

    g = rmat_graph(scale=6, edge_factor=4, seed=seed % 1000)
    pg, _ = partition_graph(g, n_shards=2, edge_block=64, vertex_pad=16)
    kl = build_kernel_layout(pg, BLK=16, SRC_WIN=16, DST_WIN=16)
    rng = np.random.default_rng(seed % 97)
    P = pg.P
    state3 = jnp.stack([
        jnp.asarray(rng.random(P, dtype=np.float32)),
        jnp.asarray(np.asarray(pg.degree)[0].astype(np.float32)),
        jnp.asarray((rng.random(P) < density).astype(np.float32)),
    ], axis=0)
    i, k = 0, 1
    args = (
        state3, kl.sp[i, k], kl.dp[i, k], kl.w[i, k],
        jnp.arange(kl.NB, dtype=jnp.int32), jnp.int32(kl.NB),
        kl.blk_swin[i, k], kl.blk_dwin[i, k],
    )
    kw = dict(SRC_WIN=16, DST_WIN=16, msg_kind="div_deg", combiner="sum")
    A_k, _ = ops.edge_combine(*args, **kw)
    A_r, _ = edge_combine_ref(*args, **kw)
    np.testing.assert_allclose(np.asarray(A_k), np.asarray(A_r),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# varint-delta codec: arbitrary integer streams round-trip (streams/codec.py)
# ---------------------------------------------------------------------------

@given(
    st.lists(st.integers(-(2**50), 2**50), min_size=0, max_size=300),
    st.booleans(),
)
@settings(max_examples=60, deadline=None)
def test_property_varint_delta_roundtrip(vals, presort):
    """encode∘decode == id for sorted (the real use: dst_pos columns) AND
    unsorted input (zigzag covers sign flips, e.g. the -1 padding tail)."""
    from repro.streams.codec import decode_varint_delta, encode_varint_delta

    v = np.array(sorted(vals) if presort else vals, dtype=np.int64)
    out = decode_varint_delta(encode_varint_delta(v))
    assert np.array_equal(out, v)


@given(
    st.lists(st.integers(0, 2**31 - 1), min_size=1, max_size=300),
    st.integers(1, 64),
)
@settings(max_examples=40, deadline=None)
def test_property_streaming_decoder_matches_bulk(vals, take):
    """Chunked streaming decode == bulk decode for every take size (the
    merge cursors rely on this to keep O(read_chunk) residency)."""
    from repro.streams.codec import (
        VarintDeltaDecoder, decode_varint_delta, encode_varint_delta,
    )

    v = np.array(sorted(vals), dtype=np.int64)
    blob = encode_varint_delta(v)
    dec = VarintDeltaDecoder(blob, len(v))
    parts = []
    while dec.remaining:
        parts.append(dec.take(take))
    assert np.array_equal(np.concatenate(parts), decode_varint_delta(blob))


# ---------------------------------------------------------------------------
# channel ordering: arbitrary interleavings of per-shard appends must merge
# into destination-sorted runs (streams/channel.py + msgstore external merge)
# ---------------------------------------------------------------------------

@given(
    st.lists(  # per packet: (source shard, destination shard, run length)
        st.tuples(st.integers(0, 2), st.integers(0, 2), st.integers(0, 40)),
        min_size=0, max_size=25,
    ),
    st.integers(0, 2**31 - 1),
    st.booleans(),
)
@settings(max_examples=25, deadline=None)
def test_property_channel_interleavings_merge_sorted(packets, seed, compress):
    """Whatever interleaving of per-shard sends (and whatever payload), each
    inbox's k-way merge must yield one globally destination-sorted stream
    holding exactly the multiset of transmitted messages."""
    import tempfile

    from repro.streams import MessageRunStore, ShardChannels

    P = 32
    rng = np.random.default_rng(seed % (2**32))
    with tempfile.TemporaryDirectory(prefix="graphd-chan-prop-") as d:
        store = MessageRunStore(d, 3, P, np.float32, compress=compress)
        chan = ShardChannels(store, inflight=2)
        want = {k: [] for k in range(3)}
        for src, k, ln in packets:
            dp = np.sort(rng.integers(0, P, ln)).astype(np.int32)
            msg = rng.random(ln).astype(np.float32)
            chan.send(k, dp, msg, tag=src)
            want[k].append((dp, msg))
        chan.close()
        for k in range(3):
            merged = list(store.iter_merged(k, read_chunk=7))
            got_dp = (np.concatenate([m[0] for m in merged])
                      if merged else np.empty(0, np.int64))
            got_msg = (np.concatenate([m[1] for m in merged])
                       if merged else np.empty(0, np.float32))
            all_dp = (np.concatenate([dp for dp, _ in want[k]])
                      if want[k] else np.empty(0, np.int32))
            all_msg = (np.concatenate([m for _, m in want[k]])
                       if want[k] else np.empty(0, np.float32))
            assert np.all(np.diff(got_dp) >= 0)
            # multiset equality of (dst, payload) pairs
            ow = np.lexsort((all_msg, all_dp))
            og = np.lexsort((got_msg, got_dp))
            assert np.array_equal(all_dp[ow], got_dp[og])
            assert np.array_equal(all_msg[ow], got_msg[og])
