"""The outbox→inbox channel layer (streams/channel.py) and the varint-delta
codec (streams/codec.py): fixed-seed versions of the load-bearing checks
(the hypothesis sweeps live in tests/test_properties.py and skip without the
package), plus compressed-store and dead-region-reclamation coverage."""

import os
import threading
import time

import numpy as np
import pytest

from repro.streams import (
    ChannelError, FaultPoint, MessageRunStore, ShardChannels,
    VarintDeltaDecoder, decode_varint_delta, encode_varint_delta,
)


# ---------------------------------------------------------------------------
# codec
# ---------------------------------------------------------------------------

class TestVarintDeltaCodec:
    CASES = [
        np.array([], np.int64),
        np.array([0], np.int32),
        np.array([7, 7, 7, 7], np.int32),
        np.arange(1000, dtype=np.int32),
        np.array([5, 3, 1, -1, -1, -1], np.int32),  # sorted run + padding
        np.array([2**31 - 1, 0, -(2**31)], np.int64),
        # bit-63 zigzag range: a signed un-zigzag shift used to corrupt these
        np.array([2**62, -(2**62), 2**63 - 1, -(2**63) + 1, 0], np.int64),
        np.array([0, 2**63 - 1], np.int64),  # delta wraps mod 2^64
    ]

    @pytest.mark.parametrize("case", range(len(CASES)))
    def test_roundtrip(self, case):
        v = self.CASES[case]
        out = decode_varint_delta(encode_varint_delta(v))
        assert np.array_equal(out, v.astype(np.int64))

    def test_random_roundtrips(self):
        rng = np.random.default_rng(0)
        for _ in range(50):
            n = int(rng.integers(1, 400))
            v = (np.sort(rng.integers(0, 1 << 20, n)) if rng.random() < 0.5
                 else rng.integers(-(1 << 40), 1 << 40, n))
            assert np.array_equal(
                decode_varint_delta(encode_varint_delta(v)), v
            )

    def test_chained_chunks_equal_whole(self):
        rng = np.random.default_rng(1)
        v = np.sort(rng.integers(0, 10_000, 777))
        cut = 300
        b1 = encode_varint_delta(v[:cut])
        b2 = encode_varint_delta(v[cut:], prev=int(v[cut - 1]))
        got = np.concatenate([
            decode_varint_delta(b1),
            decode_varint_delta(b2, prev=int(v[cut - 1])),
        ])
        assert np.array_equal(got, v)
        # and the two chained blobs ARE the whole blob, byte for byte
        assert b1 + b2 == encode_varint_delta(v)

    def test_streaming_decoder_bounded_takes(self):
        rng = np.random.default_rng(2)
        v = np.sort(rng.integers(0, 1 << 16, 1234))
        dec = VarintDeltaDecoder(encode_varint_delta(v), len(v))
        parts = []
        while dec.remaining:
            parts.append(dec.take(int(rng.integers(1, 100))))
        assert np.array_equal(np.concatenate(parts), v)

    def test_sorted_positions_compress_hard(self):
        """The point of the knob: a dense sorted dst_pos column must shrink
        well below 4 bytes/value (most deltas fit one byte)."""
        rng = np.random.default_rng(3)
        v = np.sort(rng.integers(0, 1 << 14, 50_000))
        assert len(encode_varint_delta(v)) < 0.3 * v.size * 4

    def test_truncated_stream_raises(self):
        blob = encode_varint_delta(np.array([1 << 40]))
        with pytest.raises(ValueError, match="truncated"):
            decode_varint_delta(blob[:-1])


# ---------------------------------------------------------------------------
# the channel layer
# ---------------------------------------------------------------------------

def _mk_store(tmp_path, n=3, P=64, compress=False, name="inbox"):
    return MessageRunStore(str(tmp_path / name), n, P, np.float32,
                           compress=compress)


class TestShardChannels:
    def test_interleaved_sends_yield_sorted_merged_runs(self, tmp_path):
        """Per-shard appends in an arbitrary interleaving must still merge
        into one destination-sorted stream per inbox (fixed-seed version of
        the hypothesis property)."""
        rng = np.random.default_rng(0)
        n, P = 3, 64
        store = _mk_store(tmp_path, n, P)
        chan = ShardChannels(store, inflight=2)
        sent = {k: [] for k in range(n)}
        packets = []
        for src in range(n):
            for _ in range(5):
                k = int(rng.integers(0, n))
                dp = np.sort(rng.integers(0, P, 40)).astype(np.int32)
                msg = rng.random(40).astype(np.float32)
                packets.append((k, dp, msg, src))
        rng.shuffle(packets)  # arbitrary interleaving across sources
        for k, dp, msg, src in packets:
            chan.send(k, dp, msg, tag=src)
            sent[k].append((dp, msg))
        chan.close()
        for k in range(n):
            merged = list(store.iter_merged(k, read_chunk=16))
            got_dp = (np.concatenate([m[0] for m in merged])
                      if merged else np.empty(0, np.int32))
            want = (np.concatenate([dp for dp, _ in sent[k]])
                    if sent[k] else np.empty(0, np.int32))
            assert np.all(np.diff(got_dp) >= 0)  # destination-sorted
            assert np.array_equal(np.sort(want), got_dp[np.argsort(
                np.argsort(got_dp, kind="stable"), kind="stable")])
            assert np.array_equal(np.sort(want), np.sort(got_dp))

    def test_send_raw_sorts_on_sender_thread(self, tmp_path):
        store = _mk_store(tmp_path)
        chan = ShardChannels(store, inflight=2)
        dp = np.array([9, 3, 7, 3, 0], np.int32)
        msg = np.array([9., 3., 7., 3.5, 0.], np.float32)
        valid = np.array([True, True, False, True, True])
        chan.send_raw(1, dp, msg, valid, tag=0)
        chan.flush()
        got_dp, got_msg = store.read_run(1, store.runs(1)[0])
        assert np.array_equal(got_dp, [0, 3, 3, 9])
        assert np.array_equal(got_msg, [0., 3., 3.5, 9.])  # stable sort
        chan.close()

    def test_flush_is_a_barrier(self, tmp_path):
        store = _mk_store(tmp_path)
        chan = ShardChannels(store, inflight=8)
        for j in range(6):
            chan.send(0, np.arange(10, dtype=np.int32),
                      np.full(10, float(j), np.float32), tag=0)
        chan.flush()
        assert len(store.runs(0)) == 6  # every packet landed before return
        chan.close()

    def test_fifo_order_preserved(self, tmp_path):
        """Run-table order == send order: the pipelined engine's results
        depend on it (digest folds in transmit order)."""
        store = _mk_store(tmp_path)
        chan = ShardChannels(store, inflight=1)
        for j in range(10):
            chan.send(0, np.array([j], np.int32),
                      np.array([float(j)], np.float32), tag=j)
        chan.close()
        assert [s.tag for s in store.runs(0)] == list(range(10))

    def test_compact_op_runs_in_order(self, tmp_path):
        store = _mk_store(tmp_path)
        chan = ShardChannels(store, inflight=2)
        rng = np.random.default_rng(1)
        for _ in range(4):
            dp = np.sort(rng.integers(0, 64, 30)).astype(np.int32)
            chan.send(2, dp, rng.random(30).astype(np.float32), tag=5)
        chan.compact(2, 5, fanin=2, read_chunk=8)
        chan.flush()
        assert len([s for s in store.runs(2) if s.tag == 5]) == 1
        assert store.n_messages(2) == 120
        chan.close()

    def test_fault_surfaces_as_channel_error(self, tmp_path):
        store = _mk_store(tmp_path)
        fault = FaultPoint(after_packets=3)
        chan = ShardChannels(store, inflight=1, fault=fault)
        with pytest.raises(ChannelError) as ei:
            for j in range(50):
                chan.send(0, np.array([j], np.int32),
                          np.array([0.], np.float32))
            chan.flush()
        assert fault.fired
        assert "injected" in str(ei.value.__cause__)
        # exactly the packets before the fault landed — no torn extras
        assert len(store.runs(0)) == 3
        chan.abort()  # crash-path cleanup never raises

    def test_flush_raises_when_sender_died_before_barrier(self, tmp_path):
        """Regression: the death-path drain sets pending barrier events to
        wake their waiters — flush() must still RAISE, not report success,
        because the ops ahead of the drained barrier never landed."""
        store = _mk_store(tmp_path)
        chan = ShardChannels(store, inflight=16, fault=FaultPoint(2))
        for j in range(5):  # all queue without blocking (budget is 16)
            chan.send(0, np.array([j], np.int32),
                      np.array([0.], np.float32))
        with pytest.raises(ChannelError):
            chan.flush()
        assert len(store.runs(0)) == 2  # only pre-fault packets landed
        chan.abort()

    def test_close_surfaces_error_even_without_blocking_send(self, tmp_path):
        store = _mk_store(tmp_path)
        chan = ShardChannels(store, inflight=16, fault=FaultPoint(1))
        chan.send(0, np.array([1], np.int32), np.array([1.], np.float32))
        with pytest.raises(ChannelError):
            chan.close()

    def test_stats_account_packets_and_overlap(self, tmp_path):
        store = _mk_store(tmp_path)
        chan = ShardChannels(store, inflight=4)
        for _ in range(8):
            chan.send(1, np.arange(50, dtype=np.int32),
                      np.zeros(50, np.float32))
            time.sleep(0.002)  # compute-bound producer => sender overlaps
        chan.close()
        st = chan.stats
        assert st.packets == 8
        assert st.messages == 400
        assert st.payload_bytes == 8 * 50 * 8
        assert st.send_seconds > 0
        assert st.overlap_seconds() >= 0

    def test_inflight_budget_bounds_queue(self, tmp_path):
        """The producer must block once `inflight` packets are queued — the
        O(1) memory contract. A slow sender + small budget => the producer's
        stall time is visible in the stats."""
        store = _mk_store(tmp_path)
        orig = store.append_run

        def slow_append(*a, **kw):
            time.sleep(0.01)
            return orig(*a, **kw)

        store.append_run = slow_append
        chan = ShardChannels(store, inflight=1)
        for _ in range(6):
            chan.send(0, np.arange(4, dtype=np.int32),
                      np.zeros(4, np.float32))
        chan.close()
        assert chan.stats.stall_seconds > 0

    def test_compressed_inbox_equals_plain(self, tmp_path):
        rng = np.random.default_rng(4)
        plain = _mk_store(tmp_path, name="plain")
        comp = _mk_store(tmp_path, compress=True, name="comp")
        for store in (plain, comp):
            chan = ShardChannels(store, inflight=2)
            rng2 = np.random.default_rng(7)
            for src in range(3):
                for _ in range(4):
                    dp = np.sort(rng2.integers(0, 64, 200)).astype(np.int32)
                    chan.send(1, dp, rng2.random(200).astype(np.float32),
                              tag=src)
                chan.compact(1, src, fanin=2, read_chunk=64)
            chan.close()
        a = [np.concatenate(x) for x in zip(*plain.iter_merged(1, 32))]
        b = [np.concatenate(x) for x in zip(*comp.iter_merged(1, 32))]
        assert np.array_equal(a[0], b[0])
        assert np.array_equal(a[1], b[1])
        assert comp.disk_bytes() < plain.disk_bytes()
