"""The outbox→inbox channel layer (streams/channel.py) and the varint-delta
codec (streams/codec.py): fixed-seed versions of the load-bearing checks
(the hypothesis sweeps live in tests/test_properties.py and skip without the
package), plus compressed-store and dead-region-reclamation coverage."""

import os
import threading
import time

import numpy as np
import pytest

from repro.streams import (
    ChannelError, FaultPoint, MessageRunStore, ShardChannels,
    VarintDeltaDecoder, decode_varint_delta, encode_varint_delta,
)


# ---------------------------------------------------------------------------
# codec
# ---------------------------------------------------------------------------

class TestVarintDeltaCodec:
    CASES = [
        np.array([], np.int64),
        np.array([0], np.int32),
        np.array([7, 7, 7, 7], np.int32),
        np.arange(1000, dtype=np.int32),
        np.array([5, 3, 1, -1, -1, -1], np.int32),  # sorted run + padding
        np.array([2**31 - 1, 0, -(2**31)], np.int64),
        # bit-63 zigzag range: a signed un-zigzag shift used to corrupt these
        np.array([2**62, -(2**62), 2**63 - 1, -(2**63) + 1, 0], np.int64),
        np.array([0, 2**63 - 1], np.int64),  # delta wraps mod 2^64
    ]

    @pytest.mark.parametrize("case", range(len(CASES)))
    def test_roundtrip(self, case):
        v = self.CASES[case]
        out = decode_varint_delta(encode_varint_delta(v))
        assert np.array_equal(out, v.astype(np.int64))

    def test_random_roundtrips(self):
        rng = np.random.default_rng(0)
        for _ in range(50):
            n = int(rng.integers(1, 400))
            v = (np.sort(rng.integers(0, 1 << 20, n)) if rng.random() < 0.5
                 else rng.integers(-(1 << 40), 1 << 40, n))
            assert np.array_equal(
                decode_varint_delta(encode_varint_delta(v)), v
            )

    def test_chained_chunks_equal_whole(self):
        rng = np.random.default_rng(1)
        v = np.sort(rng.integers(0, 10_000, 777))
        cut = 300
        b1 = encode_varint_delta(v[:cut])
        b2 = encode_varint_delta(v[cut:], prev=int(v[cut - 1]))
        got = np.concatenate([
            decode_varint_delta(b1),
            decode_varint_delta(b2, prev=int(v[cut - 1])),
        ])
        assert np.array_equal(got, v)
        # and the two chained blobs ARE the whole blob, byte for byte
        assert b1 + b2 == encode_varint_delta(v)

    def test_streaming_decoder_bounded_takes(self):
        rng = np.random.default_rng(2)
        v = np.sort(rng.integers(0, 1 << 16, 1234))
        dec = VarintDeltaDecoder(encode_varint_delta(v), len(v))
        parts = []
        while dec.remaining:
            parts.append(dec.take(int(rng.integers(1, 100))))
        assert np.array_equal(np.concatenate(parts), v)

    def test_sorted_positions_compress_hard(self):
        """The point of the knob: a dense sorted dst_pos column must shrink
        well below 4 bytes/value (most deltas fit one byte)."""
        rng = np.random.default_rng(3)
        v = np.sort(rng.integers(0, 1 << 14, 50_000))
        assert len(encode_varint_delta(v)) < 0.3 * v.size * 4

    def test_truncated_stream_raises(self):
        blob = encode_varint_delta(np.array([1 << 40]))
        with pytest.raises(ValueError, match="truncated"):
            decode_varint_delta(blob[:-1])


# ---------------------------------------------------------------------------
# the channel layer
# ---------------------------------------------------------------------------

def _mk_store(tmp_path, n=3, P=64, compress=False, name="inbox"):
    return MessageRunStore(str(tmp_path / name), n, P, np.float32,
                           compress=compress)


class TestShardChannels:
    def test_interleaved_sends_yield_sorted_merged_runs(self, tmp_path):
        """Per-shard appends in an arbitrary interleaving must still merge
        into one destination-sorted stream per inbox (fixed-seed version of
        the hypothesis property)."""
        rng = np.random.default_rng(0)
        n, P = 3, 64
        store = _mk_store(tmp_path, n, P)
        chan = ShardChannels(store, inflight=2)
        sent = {k: [] for k in range(n)}
        packets = []
        for src in range(n):
            for _ in range(5):
                k = int(rng.integers(0, n))
                dp = np.sort(rng.integers(0, P, 40)).astype(np.int32)
                msg = rng.random(40).astype(np.float32)
                packets.append((k, dp, msg, src))
        rng.shuffle(packets)  # arbitrary interleaving across sources
        for k, dp, msg, src in packets:
            chan.send(k, dp, msg, tag=src)
            sent[k].append((dp, msg))
        chan.close()
        for k in range(n):
            merged = list(store.iter_merged(k, read_chunk=16))
            got_dp = (np.concatenate([m[0] for m in merged])
                      if merged else np.empty(0, np.int32))
            want = (np.concatenate([dp for dp, _ in sent[k]])
                    if sent[k] else np.empty(0, np.int32))
            assert np.all(np.diff(got_dp) >= 0)  # destination-sorted
            assert np.array_equal(np.sort(want), got_dp[np.argsort(
                np.argsort(got_dp, kind="stable"), kind="stable")])
            assert np.array_equal(np.sort(want), np.sort(got_dp))

    def test_send_raw_sorts_on_sender_thread(self, tmp_path):
        store = _mk_store(tmp_path)
        chan = ShardChannels(store, inflight=2)
        dp = np.array([9, 3, 7, 3, 0], np.int32)
        msg = np.array([9., 3., 7., 3.5, 0.], np.float32)
        valid = np.array([True, True, False, True, True])
        chan.send_raw(1, dp, msg, valid, tag=0)
        chan.flush()
        got_dp, got_msg = store.read_run(1, store.runs(1)[0])
        assert np.array_equal(got_dp, [0, 3, 3, 9])
        assert np.array_equal(got_msg, [0., 3., 3.5, 9.])  # stable sort
        chan.close()

    def test_flush_is_a_barrier(self, tmp_path):
        store = _mk_store(tmp_path)
        chan = ShardChannels(store, inflight=8)
        for j in range(6):
            chan.send(0, np.arange(10, dtype=np.int32),
                      np.full(10, float(j), np.float32), tag=0)
        chan.flush()
        assert len(store.runs(0)) == 6  # every packet landed before return
        chan.close()

    def test_fifo_order_preserved(self, tmp_path):
        """Run-table order == send order: the pipelined engine's results
        depend on it (digest folds in transmit order)."""
        store = _mk_store(tmp_path)
        chan = ShardChannels(store, inflight=1)
        for j in range(10):
            chan.send(0, np.array([j], np.int32),
                      np.array([float(j)], np.float32), tag=j)
        chan.close()
        assert [s.tag for s in store.runs(0)] == list(range(10))

    def test_compact_op_runs_in_order(self, tmp_path):
        store = _mk_store(tmp_path)
        chan = ShardChannels(store, inflight=2)
        rng = np.random.default_rng(1)
        for _ in range(4):
            dp = np.sort(rng.integers(0, 64, 30)).astype(np.int32)
            chan.send(2, dp, rng.random(30).astype(np.float32), tag=5)
        chan.compact(2, 5, fanin=2, read_chunk=8)
        chan.flush()
        assert len([s for s in store.runs(2) if s.tag == 5]) == 1
        assert store.n_messages(2) == 120
        chan.close()

    def test_fault_surfaces_as_channel_error(self, tmp_path):
        store = _mk_store(tmp_path)
        fault = FaultPoint(after_packets=3)
        chan = ShardChannels(store, inflight=1, fault=fault)
        with pytest.raises(ChannelError) as ei:
            for j in range(50):
                chan.send(0, np.array([j], np.int32),
                          np.array([0.], np.float32))
            chan.flush()
        assert fault.fired
        assert "injected" in str(ei.value.__cause__)
        # exactly the packets before the fault landed — no torn extras
        assert len(store.runs(0)) == 3
        chan.abort()  # crash-path cleanup never raises

    def test_flush_raises_when_sender_died_before_barrier(self, tmp_path):
        """Regression: the death-path drain sets pending barrier events to
        wake their waiters — flush() must still RAISE, not report success,
        because the ops ahead of the drained barrier never landed."""
        store = _mk_store(tmp_path)
        chan = ShardChannels(store, inflight=16, fault=FaultPoint(2))
        for j in range(5):  # all queue without blocking (budget is 16)
            chan.send(0, np.array([j], np.int32),
                      np.array([0.], np.float32))
        with pytest.raises(ChannelError):
            chan.flush()
        assert len(store.runs(0)) == 2  # only pre-fault packets landed
        chan.abort()

    def test_close_surfaces_error_even_without_blocking_send(self, tmp_path):
        store = _mk_store(tmp_path)
        chan = ShardChannels(store, inflight=16, fault=FaultPoint(1))
        chan.send(0, np.array([1], np.int32), np.array([1.], np.float32))
        with pytest.raises(ChannelError):
            chan.close()

    def test_stats_account_packets_and_overlap(self, tmp_path):
        store = _mk_store(tmp_path)
        chan = ShardChannels(store, inflight=4)
        for _ in range(8):
            chan.send(1, np.arange(50, dtype=np.int32),
                      np.zeros(50, np.float32))
            time.sleep(0.002)  # compute-bound producer => sender overlaps
        chan.close()
        st = chan.stats
        assert st.packets == 8
        assert st.messages == 400
        assert st.payload_bytes == 8 * 50 * 8
        assert st.send_seconds > 0
        assert st.overlap_seconds() >= 0

    def test_inflight_budget_bounds_queue(self, tmp_path):
        """The producer must block once `inflight` packets are queued — the
        O(1) memory contract. A slow sender + small budget => the producer's
        stall time is visible in the stats."""
        store = _mk_store(tmp_path)
        orig = store.append_run

        def slow_append(*a, **kw):
            time.sleep(0.01)
            return orig(*a, **kw)

        store.append_run = slow_append
        chan = ShardChannels(store, inflight=1)
        for _ in range(6):
            chan.send(0, np.arange(4, dtype=np.int32),
                      np.zeros(4, np.float32))
        chan.close()
        assert chan.stats.stall_seconds > 0

    def test_compressed_inbox_equals_plain(self, tmp_path):
        rng = np.random.default_rng(4)
        plain = _mk_store(tmp_path, name="plain")
        comp = _mk_store(tmp_path, compress=True, name="comp")
        for store in (plain, comp):
            chan = ShardChannels(store, inflight=2)
            rng2 = np.random.default_rng(7)
            for src in range(3):
                for _ in range(4):
                    dp = np.sort(rng2.integers(0, 64, 200)).astype(np.int32)
                    chan.send(1, dp, rng2.random(200).astype(np.float32),
                              tag=src)
                chan.compact(1, src, fanin=2, read_chunk=64)
            chan.close()
        a = [np.concatenate(x) for x in zip(*plain.iter_merged(1, 32))]
        b = [np.concatenate(x) for x in zip(*comp.iter_merged(1, 32))]
        assert np.array_equal(a[0], b[0])
        assert np.array_equal(a[1], b[1])
        assert comp.disk_bytes() < plain.disk_bytes()


# ---------------------------------------------------------------------------
# payload codec (PR 5: value columns on the wire)
# ---------------------------------------------------------------------------

class TestPayloadCodec:
    def test_lossless_roundtrip_f32_and_i32(self):
        from repro.streams import decode_payload, encode_payload

        rng = np.random.default_rng(0)
        for arr in (
            np.empty((0,), np.float32),
            rng.random(1, dtype=np.float32),
            (rng.random(10_000, dtype=np.float32) * 1e-2).astype(np.float32),
            rng.integers(0, 30, 9001).astype(np.int32),
            np.array([np.inf, -np.inf, np.nan, 0.0, -0.0], np.float32),
        ):
            blob = encode_payload(arr)
            out = decode_payload(blob, arr.dtype, arr.size)
            # bit-exact, NaN included
            assert arr.tobytes() == out.tobytes()

    def test_bf16_scheme_matches_jax_rounding(self):
        import jax.numpy as jnp

        from repro.streams import decode_payload, encode_payload

        rng = np.random.default_rng(1)
        x = (rng.standard_normal(4097) * rng.choice(
            [1e-8, 1.0, 1e8], 4097)).astype(np.float32)
        # NaN payloads must stay NaN (the rounding bias must not carry the
        # NaN mantissa into the exponent and yield ±0), infinities and
        # overflow-to-inf must match the XLA convert too
        x[:8] = [np.nan, -np.nan, np.inf, -np.inf, 0.0, -0.0, 3.4e38,
                 -3.4e38]
        got = decode_payload(encode_payload(x, "bf16"), np.float32, x.size,
                             "bf16")
        want = np.asarray(
            jnp.asarray(x).astype(jnp.bfloat16).astype(jnp.float32))
        assert np.array_equal(got, want, equal_nan=True)
        assert np.isnan(got[:2]).all()

    def test_chunked_encoder_equals_one_shot(self):
        from repro.streams import PayloadEncoder, encode_payload

        rng = np.random.default_rng(2)
        x = rng.random(11_111, dtype=np.float32)
        enc = PayloadEncoder(np.float32)
        parts, off = [], 0
        while off < x.size:
            n = int(rng.integers(1, 700))
            parts.append(enc.add(x[off:off + n]))
            off += n
        parts.append(enc.flush())
        assert b"".join(parts) == encode_payload(x)

    def test_streaming_decoder_bounded_takes(self):
        from repro.streams import PayloadDecoder, encode_payload

        rng = np.random.default_rng(3)
        x = rng.integers(-5, 5, 10_000).astype(np.int32)
        dec = PayloadDecoder(encode_payload(x), np.int32, x.size)
        got = []
        while dec.remaining:
            got.append(dec.take(int(rng.integers(1, 999))))
        assert np.array_equal(np.concatenate(got), x)

    def test_truncated_blob_raises(self):
        from repro.streams import decode_payload, encode_payload

        blob = encode_payload(np.arange(100, dtype=np.int32))
        with pytest.raises(ValueError):
            decode_payload(blob[: len(blob) // 2], np.int32, 100)

    def test_bf16_requires_float32(self):
        from repro.streams import encode_payload

        with pytest.raises(ValueError):
            encode_payload(np.arange(4, dtype=np.int32), "bf16")


class TestPayloadCompressedChannel:
    def test_payload_inbox_equals_plain_and_is_smaller(self, tmp_path):
        plain = _mk_store(tmp_path, name="plain")
        comp = MessageRunStore(str(tmp_path / "payload"), 3, 64, np.float32,
                               compress=True, compress_payload=True)
        for store in (plain, comp):
            chan = ShardChannels(store, inflight=2)
            rng = np.random.default_rng(7)
            for src in range(3):
                for _ in range(4):
                    dp = np.sort(rng.integers(0, 64, 500)).astype(np.int32)
                    chan.send(1, dp, (rng.random(500) * 1e-2).astype(
                        np.float32), tag=src)
                chan.compact(1, src, fanin=2, read_chunk=64)
            chan.close()
            store.save_index()
        a = [np.concatenate(x) for x in zip(*plain.iter_merged(1, 32))]
        b = [np.concatenate(x) for x in zip(*comp.iter_merged(1, 32))]
        assert np.array_equal(a[0], b[0])
        assert np.array_equal(a[1], b[1])  # lossless: payload bit-identical
        assert comp.disk_bytes() < plain.disk_bytes()
        # ... and the index round-trips the payload layout
        re = MessageRunStore.open(str(tmp_path / "payload"))
        c = [np.concatenate(x) for x in zip(*re.iter_merged(1, 32))]
        assert np.array_equal(a[1], c[1])

    def test_wire_bytes_accounting(self, tmp_path):
        comp = MessageRunStore(str(tmp_path / "p"), 2, 64, np.float32,
                               with_counts=True, compress=True,
                               compress_payload=True)
        chan = ShardChannels(comp, inflight=2)
        rng = np.random.default_rng(5)
        A = (rng.random(64) * 1e-2).astype(np.float32)
        cnt = rng.integers(0, 3, 64).astype(np.int32)
        chan.send_combined(0, A, cnt, tag=1)
        chan.close()
        st = chan.stats
        assert st.wire_bytes > 0
        assert st.wire_bytes < st.payload_bytes  # the codecs shrank the wire
        assert st.wire_ratio() > 1.0


# ---------------------------------------------------------------------------
# the background receiver (PR 5: full duplex)
# ---------------------------------------------------------------------------

class TestChannelReceiver:
    def _receiver(self, store, fault=None):
        from repro.streams import ChannelReceiver

        order = []

        def digest(A, c, A_d, c_d):
            order.append(int(c_d[np.nonzero(c_d)[0][0]])
                         if np.any(c_d) else -1)
            return A + A_d, c + c_d

        identity = lambda: (np.zeros(store.P, np.float32),
                            np.zeros(store.P, np.int32))
        return ChannelReceiver(store, digest, identity, 0.0,
                               fault=fault), order

    def test_digest_order_is_transmit_order(self, tmp_path):
        store = MessageRunStore(str(tmp_path / "i"), 2, 16, np.float32,
                                with_counts=True)
        recv, order = self._receiver(store)
        chan = ShardChannels(store, inflight=2, receiver=recv)
        for j in range(1, 6):  # tag each run by its cnt value
            A = np.full(16, float(j), np.float32)
            cnt = np.full(16, j, np.int32)
            chan.send_combined(0, A, cnt, tag=j % 2)
        chan.flush()
        A_r, cnt = recv.collect(0)
        assert order == [1, 2, 3, 4, 5]  # append order == digest order
        assert np.all(cnt == sum(range(1, 6)))
        # an untouched destination collects the identity
        A_e, c_e = recv.collect(1)
        assert not np.any(c_e)
        chan.close()
        recv.close()

    def test_receiver_fault_surfaces_on_collect(self, tmp_path):
        store = MessageRunStore(str(tmp_path / "i"), 2, 16, np.float32,
                                with_counts=True)
        recv, _ = self._receiver(store, fault=FaultPoint(after_packets=2))
        chan = ShardChannels(store, inflight=4, receiver=recv)
        for j in range(4):
            chan.send_combined(0, np.ones(16, np.float32),
                               np.ones(16, np.int32), tag=j)
        chan.flush()  # sender side is healthy
        with pytest.raises(ChannelError):
            recv.collect(0)
        chan.close()
        recv.abort()  # crash-path stop must not raise


class TestReceiveIter:
    def test_passthrough_and_stats(self):
        from repro.streams import ChannelStats, receive_iter

        stats = ChannelStats()
        items = list(receive_iter(iter(range(50)), stats=stats, depth=2))
        assert items == list(range(50))
        assert stats.recv_runs == 50
        assert stats.recv_seconds >= 0

    def test_fault_kills_producer_and_surfaces(self):
        from repro.streams import receive_iter

        fault = FaultPoint(after_packets=5)
        with pytest.raises(ChannelError):
            list(receive_iter(iter(range(50)), fault=fault))
        assert fault.fired

    def test_producer_error_wrapped(self):
        from repro.streams import receive_iter

        def gen():
            yield 1
            raise RuntimeError("disk on fire")

        with pytest.raises(ChannelError):
            list(receive_iter(gen()))
