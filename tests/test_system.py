"""End-to-end behaviour tests for the full system (paper job lifecycle)."""

import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))


def _run(args, timeout=900):
    out = subprocess.run(
        [sys.executable] + args, env=ENV, cwd=ROOT,
        capture_output=True, text=True, timeout=timeout,
    )
    assert out.returncode == 0, f"{args}:\n{out.stdout}\n{out.stderr}"
    return out.stdout


def test_quickstart_example():
    out = _run(["examples/quickstart.py"])
    assert "top-5 vertices by PageRank" in out


def test_graph_analytics_e2e():
    out = _run(["examples/graph_analytics.py"])
    assert "fast recovery of shard 5: max err 0.00e+00" in out
    assert "elastic rescale" in out
    assert "done." in out


def test_train_launcher_smoke():
    out = _run(["-m", "repro.launch.train", "--arch", "minitron-4b",
                "--reduced", "--steps", "8", "--batch", "4",
                "--seq", "64"])
    assert "done:" in out
    assert "loss" in out


def test_train_launcher_resume(tmp_path):
    ck = str(tmp_path / "ck")
    _run(["-m", "repro.launch.train", "--arch", "minitron-4b", "--reduced",
          "--steps", "6", "--batch", "2", "--seq", "32",
          "--ckpt-every", "3", "--ckpt-dir", ck])
    out = _run(["-m", "repro.launch.train", "--arch", "minitron-4b",
                "--reduced", "--steps", "9", "--batch", "2", "--seq", "32",
                "--ckpt-dir", ck, "--resume"])
    assert "resumed at step 6" in out


def test_serve_launcher_smoke():
    out = _run(["-m", "repro.launch.serve", "--arch", "gemma3-12b",
                "--reduced", "--batch", "2", "--prompt-len", "16",
                "--gen", "8"])
    assert "generated (2, 8)" in out


def test_moe_example():
    out = _run(["examples/moe_expert_stats.py"])
    assert "load-balance aux" in out
