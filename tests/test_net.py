"""Socket transport (launch/net.py): frame protocol (CRC, torn frames), the
run wire codec round-trip, the coordinator plane (register/arrive/commit/
abort over one connection), the data plane's sender/receiver pair with the
reconnect-with-resume handshake, and the planner's measured link probes.
Everything here is stdlib + numpy — no jax, no engine."""

import json
import socket
import threading
import time
import zlib

import numpy as np
import pytest

from repro.core.coordinator import RunAborted, atomic_write_json
from repro.fault import RetryPolicy
from repro.launch.net import (
    _HEADER,
    MAGIC,
    CoordClient,
    CoordServer,
    FrameError,
    K_ARRIVE,
    K_RUN,
    PeerSender,
    PeerServer,
    TornFrame,
    decode_run,
    encode_run,
    probe_file_throughput,
    probe_link_throughput,
    recv_frame,
    send_frame,
)


# -- framing -------------------------------------------------------------------

class TestFraming:
    def test_round_trip(self):
        a, b = socket.socketpair()
        try:
            for kind, payload in [(K_RUN, b"hello"), (7, b""),
                                  (K_ARRIVE, b"\x00" * 4096)]:
                wire = send_frame(a, kind, payload)
                assert wire == _HEADER.size + len(payload)
                got_kind, got = recv_frame(b)
                assert got_kind == kind and got == payload
        finally:
            a.close()
            b.close()

    def test_crc_mismatch_is_frame_error(self):
        a, b = socket.socketpair()
        try:
            payload = b"payload bytes"
            hdr = _HEADER.pack(MAGIC, K_RUN, len(payload),
                               zlib.crc32(payload) ^ 0xDEAD)
            a.sendall(hdr + payload)
            with pytest.raises(FrameError, match="CRC"):
                recv_frame(b)
        finally:
            a.close()
            b.close()

    def test_bad_magic_is_frame_error(self):
        a, b = socket.socketpair()
        try:
            a.sendall(_HEADER.pack(0x12345678, K_RUN, 0, zlib.crc32(b"")))
            with pytest.raises(FrameError, match="magic"):
                recv_frame(b)
        finally:
            a.close()
            b.close()

    def test_torn_frame_on_eof_mid_payload(self):
        """The crash-drill shape: header + half the payload, then the peer
        dies. The reader must raise TornFrame — the partial bytes are
        discarded, never surfaced as a run."""
        a, b = socket.socketpair()
        try:
            payload = b"x" * 1000
            hdr = _HEADER.pack(MAGIC, K_RUN, len(payload),
                               zlib.crc32(payload))
            a.sendall(hdr + payload[: len(payload) // 2])
            a.close()  # SIGKILL's FIN
            with pytest.raises(TornFrame):
                recv_frame(b)
        finally:
            b.close()

    def test_eof_between_frames_is_torn(self):
        a, b = socket.socketpair()
        a.close()
        try:
            with pytest.raises(TornFrame):
                recv_frame(b)
        finally:
            b.close()


# -- run wire codec ------------------------------------------------------------

class TestRunCodec:
    def test_raw_run_round_trip(self):
        dp = np.array([0, 3, 3, 7, 12], np.int32)
        msg = np.array([1.5, -2.0, 0.25, 3.0, 9.0], np.float32)
        payload = encode_run(step=4, seq=2, tag=1, dp=dp, msg=msg, cnt=None)
        hdr, dp2, msg2, cnt2 = decode_run(payload)
        assert (hdr["step"], hdr["seq"], hdr["tag"]) == (4, 2, 1)
        assert cnt2 is None
        assert np.array_equal(dp2, dp)
        assert msg2.dtype == np.float32 and np.array_equal(msg2, msg)

    def test_combined_run_with_counts(self):
        dp = np.array([1, 5, 6], np.int32)
        msg = np.array([7, 8, 9], np.int64)
        cnt = np.array([2, 1, 4], np.int32)
        hdr, dp2, msg2, cnt2 = decode_run(
            encode_run(step=0, seq=0, tag=2, dp=dp, msg=msg, cnt=cnt))
        assert hdr["cnt"] is True
        assert np.array_equal(dp2, dp)
        assert msg2.dtype == np.int64 and np.array_equal(msg2, msg)
        assert np.array_equal(cnt2, cnt)  # counts are ALWAYS raw/exact

    def test_compressed_wire_formats_round_trip(self):
        """varint-delta on the sorted dp column + the lossless payload codec
        on the value column: smaller on the wire, bit-identical back."""
        dp = np.sort(np.random.default_rng(0).integers(
            0, 1 << 20, 500)).astype(np.int32)
        msg = np.random.default_rng(1).normal(size=500).astype(np.float32)
        raw = encode_run(step=1, seq=0, tag=0, dp=dp, msg=msg, cnt=None)
        packed = encode_run(step=1, seq=0, tag=0, dp=dp, msg=msg, cnt=None,
                            compress=True, scheme="lossless")
        hdr, dp2, msg2, _ = decode_run(packed)
        assert hdr["dp_enc"] and hdr["scheme"] == "lossless"
        assert np.array_equal(dp2, dp)
        assert msg2.tobytes() == msg.tobytes()  # bit-identical floats
        assert len(packed) < len(raw)

    def test_empty_run(self):
        hdr, dp, msg, cnt = decode_run(encode_run(
            step=0, seq=0, tag=0, dp=np.empty(0, np.int32),
            msg=np.empty(0, np.float32), cnt=None,
            compress=True, scheme="lossless"))
        assert hdr["n"] == 0 and dp.size == 0 and msg.size == 0


# -- coordinator plane ---------------------------------------------------------

def _register_all(server, n, **kw):
    clients = [CoordClient(server.addr, w, **kw) for w in range(n)]
    peers = [None] * n
    threads = []
    for w, c in enumerate(clients):
        c.start()

        def reg(w=w, c=c):
            peers[w] = c.register(("127.0.0.1", 20000 + w))

        t = threading.Thread(target=reg)
        t.start()
        threads.append(t)
    for t in threads:
        t.join(timeout=10)
    return clients, peers


class TestCoordPlane:
    def test_register_arrive_commit_abort(self):
        srv = CoordServer(2, heartbeat_timeout=5.0)
        srv.start()
        clients = []
        try:
            clients, peers = _register_all(srv, 2)
            # every worker got the full data-plane address table
            assert peers[0] == peers[1]
            assert [a[1] for a in peers[0]] == [20000, 20001]

            stats = dict(n_active=3, n_msgs=7, agg=0.5, active_blocks=1)
            clients[0].arrive(0, 0, stats)
            clients[1].arrive(0, 1, dict(stats, n_active=4))
            got = srv.wait_arrivals(0)
            assert set(got) == {0, 1} and got[1]["n_active"] == 4
            totals = srv.reduce_arrivals(got)
            assert totals["n_active"] == 7 and totals["agg"] == 1.0

            rec = srv.publish_commit(0, totals, halt=False, ckpt_landed=True)
            for c in clients:  # pushed, event-driven barrier
                assert c.wait_commit(0, c.shard) == rec

            # heartbeats flowed after registration
            deadline = time.time() + 5
            while srv.heartbeat_age(0) == float("inf"):
                assert time.time() < deadline, "no heartbeat arrived"
                time.sleep(0.01)
            assert not srv.stale(0)

            srv.abort("drill")
            with pytest.raises(RunAborted, match="drill"):
                clients[0].wait_commit(1, 0)
            with pytest.raises(RunAborted, match="drill"):
                clients[1].check_abort()
        finally:
            for c in clients:
                c.close()
            srv.close()

    def test_vanished_coordinator_aborts_on_retry_exhaustion(self):
        """A dead coordinator is no longer an instant poison pill: the
        client retries under its RetryPolicy, and only an exhausted budget
        aborts — loudly, with a structured failure summary."""
        srv = CoordServer(1)
        srv.start()
        retry = RetryPolicy(max_attempts=3, base_delay=0.02, max_delay=0.05,
                            deadline=5.0)
        clients, _ = _register_all(srv, 1, retry=retry)
        try:
            srv.close()  # the coordinator dies for good
            with pytest.raises(RunAborted, match="retry budget exhausted"):
                clients[0].wait_commit(0, 0)
            assert clients[0].failure is not None
            assert clients[0].failure["kind"] == "retry-exhausted"
            assert clients[0].failure["attempts"] == 3
        finally:
            clients[0].close()

    def test_coordinator_restart_reconnects_and_resumes(self, tmp_path):
        """The crash-recovery contract end to end at the protocol level: a
        coordinator with a WAL dies between a worker's arrival and the
        commit; a successor restores the WAL, the client rediscovers it
        through the address file, re-registers, and replays the stranded
        arrival — the barrier commits as if nothing happened."""
        wal = str(tmp_path / "coord-wal")
        addr_file = str(tmp_path / "coord-addr.json")
        srv = CoordServer(1, wal_dir=wal)
        atomic_write_json(addr_file,
                          dict(incarnation=0, addr=list(srv.addr)))
        srv.start()
        retry = RetryPolicy(base_delay=0.02, max_delay=0.1, deadline=30.0)
        client = CoordClient(shard=0, addr_file=addr_file, retry=retry)
        client.start()
        srv2 = None
        try:
            t = threading.Thread(
                target=lambda: client.register(("127.0.0.1", 20000)))
            t.start()
            t.join(timeout=10)
            assert not t.is_alive()
            stats = dict(n_active=1, n_msgs=0, agg=0.0, active_blocks=1)
            client.arrive(0, 0, stats)
            rec0 = srv.publish_commit(
                0, srv.reduce_arrivals(srv.wait_arrivals(0)),
                halt=False, ckpt_landed=False)
            assert client.wait_commit(0, 0) == rec0
            srv.close()  # SIGKILL stand-in: dies with step 1 in flight
            client.arrive(1, 0, stats)  # stranded; cached for replay
            srv2 = CoordServer(1, wal_dir=wal)
            assert srv2.last_commit_step() == 0  # WAL restored the commit
            atomic_write_json(addr_file,
                              dict(incarnation=1, addr=list(srv2.addr)))
            srv2.start()
            got = srv2.wait_arrivals(1)  # replayed after the reconnect
            assert set(got) == {0}
            srv2.publish_commit(1, srv2.reduce_arrivals(got),
                                halt=True, ckpt_landed=False)
            assert client.wait_commit(1, 0)["step"] == 1
            assert client.aborted() is None
        finally:
            client.close()
            if srv2 is not None:
                srv2.close()


# -- data plane ----------------------------------------------------------------

P = 16


def _mk_sender(tmp_path, me, n, **kw):
    from repro.streams.msgstore import MessageRunStore

    def make_store(step):
        return MessageRunStore(
            str(tmp_path / f"outbox-{me}" / f"step-{step:06d}"), n, P,
            np.dtype(np.float32), with_counts=True,
        )

    return PeerSender(me, n, make_store, **kw)


def _drain(server, step, src):
    runs = []
    server.read_source(step, src, lambda *a: runs.append(a), lambda: None)
    return runs


class TestDataPlane:
    def test_send_receive_combined_runs(self, tmp_path):
        """One sender, two receivers (self-loop included): each run arrives
        in the sender's append_combined transform, bit-identical."""
        servers = [PeerServer(2, start_step=0) for _ in range(2)]
        for s in servers:
            s.start()
        sender = _mk_sender(tmp_path, 0, 2)
        sender.set_addrs([s.addr for s in servers])
        sender.start()
        try:
            sender.begin_step(0)
            rng = np.random.default_rng(3)
            A = rng.normal(size=P).astype(np.float32)
            cnt = rng.integers(0, 3, P).astype(np.int32)  # zeros drop out
            for dest in range(2):
                sender.send_combined(dest, A, cnt, tag=0)
            sender.end_step()
            sender.check_failed()
            for dest, srv in enumerate(servers):
                runs = _drain(srv, 0, 0)
                assert len(runs) == 1
                hdr, dp, msg, c = runs[0]
                nz = np.nonzero(cnt > 0)[0].astype(np.int32)
                assert hdr["tag"] == 0
                assert np.array_equal(dp, nz)
                assert msg.tobytes() == A[nz].tobytes()
                assert np.array_equal(c, cnt[nz])
        finally:
            sender.close()
            for s in servers:
                s.close()

    def test_receiver_respawn_resume_replays_outbox(self, tmp_path):
        """Mid-step receiver death: runs already framed at the old address
        are NOT lost — the respawned receiver's RESUME says have=0 and the
        sender replays the whole backlog from its per-step outbox store, in
        the original append order."""
        srv = PeerServer(2, start_step=0)
        srv.start()
        self_srv = PeerServer(2, start_step=0)
        self_srv.start()
        sender = _mk_sender(tmp_path, 0, 2)
        sender.set_addrs([self_srv.addr, srv.addr])
        sender.start()
        reborn = None
        try:
            sender.begin_step(0)
            batches = []
            rng = np.random.default_rng(4)
            for i in range(2):
                A = rng.normal(size=P).astype(np.float32)
                cnt = np.ones(P, np.int32)
                batches.append(A)
                sender.send_combined(1, A, cnt, tag=0)
            srv.close()  # receiver 1 dies with two runs in flight
            reborn = PeerServer(2, start_step=0)  # respawn: new port
            reborn.start()
            sender.update_addr(1, reborn.addr)
            A = rng.normal(size=P).astype(np.float32)
            batches.append(A)
            sender.send_combined(1, A, np.ones(P, np.int32), tag=0)
            sender.send_combined(0, batches[0], np.ones(P, np.int32), tag=0)
            sender.end_step()
            sender.check_failed()
            runs = _drain(reborn, 0, 0)
            assert [hdr["seq"] for hdr, *_ in runs] == [0, 1, 2]
            for (hdr, dp, msg, c), A in zip(runs, batches):
                assert msg.tobytes() == A.tobytes()  # replay == original
            assert len(_drain(self_srv, 0, 0)) == 1  # self-loop unaffected
        finally:
            sender.close()
            for s in (srv, self_srv, reborn):
                if s is not None:
                    s.close()

    def test_duplicate_frames_after_reconnect_are_discarded(self, tmp_path):
        """The other half of resume: a receiver that already appended runs
        reports have=k, and replayed frames with seq < k are dropped — the
        digest sees every run exactly once."""
        servers = [PeerServer(2, start_step=0) for _ in range(2)]
        for s in servers:
            s.start()
        sender = _mk_sender(tmp_path, 0, 2)
        sender.set_addrs([s.addr for s in servers])
        sender.start()
        try:
            sender.begin_step(0)
            rng = np.random.default_rng(5)
            batches = [rng.normal(size=P).astype(np.float32)
                       for _ in range(3)]
            got = []
            t = threading.Thread(
                target=lambda: servers[1].read_source(
                    0, 0, lambda *a: got.append(a), lambda: None),
                daemon=True)
            t.start()
            sender.send_combined(1, batches[0], np.ones(P, np.int32), tag=0)
            sender.send_combined(1, batches[1], np.ones(P, np.int32), tag=0)
            deadline = time.time() + 10
            while len(got) < 2:  # receiver appended both live frames
                assert time.time() < deadline
                time.sleep(0.01)
            # force a reconnect: the handshake replays runs[have:] only
            sender.update_addr(1, servers[1].addr)
            sender.send_combined(1, batches[2], np.ones(P, np.int32), tag=0)
            sender.send_combined(0, batches[0], np.ones(P, np.int32), tag=0)
            sender.end_step()
            sender.check_failed()
            t.join(timeout=10)
            assert not t.is_alive()
            assert [hdr["seq"] for hdr, *_ in got] == [0, 1, 2]  # no dups
            for (hdr, dp, msg, c), A in zip(got, batches):
                assert msg.tobytes() == A.tobytes()
        finally:
            sender.close()
            for s in servers:
                s.close()


# -- link probes ---------------------------------------------------------------

class TestProbes:
    def test_link_probe_measures_positive_throughput(self):
        bw = probe_link_throughput(n_bytes=1 << 20)
        assert bw > 0

    def test_file_probe_measures_positive_throughput(self, tmp_path):
        bw = probe_file_throughput(str(tmp_path), n_bytes=1 << 20)
        assert bw > 0
        assert not any(p.name == "probe.bin" for p in tmp_path.iterdir())


class TestSendFailureEpisode:
    """A peer that keeps ACCEPTING connections but never takes a frame must
    not livelock the reconnect->replay->fail cycle: connect successes reset
    the connect-path retry episode, so the send failures themselves carry
    the budget. `_note_send_failure` bounds the consecutive-failure episode
    with the same RetryPolicy and any delivered frame resets it."""

    def _sender(self, max_attempts):
        from repro.fault import RetryExhausted

        s = PeerSender(0, 2, make_store=None,
                       retry=RetryPolicy(max_attempts=max_attempts,
                                         base_delay=0.001, max_delay=0.002,
                                         deadline=30.0))
        return s, RetryExhausted

    def test_episode_exhausts_loud_with_site(self):
        s, RetryExhausted = self._sender(max_attempts=3)
        err = OSError(32, "broken pipe")
        s._note_send_failure(1, err)
        s._note_send_failure(1, err)
        with pytest.raises(RetryExhausted) as ei:
            s._note_send_failure(1, err)
        assert ei.value.site == "peer-send:0->1"
        assert ei.value.attempts == 3
        assert ei.value.summary()["kind"] == "retry-exhausted"

    def test_delivered_frame_resets_the_episode(self):
        s, _ = self._sender(max_attempts=3)
        err = OSError(32, "broken pipe")
        s._note_send_failure(1, err)
        s._note_send_failure(1, err)
        s._send_fail.pop(1, None)  # what a successful send does
        s._note_send_failure(1, err)  # a fresh episode: attempt 1 again
        assert s._send_fail[1][1] == 1

    def test_episodes_are_per_destination(self):
        s, RetryExhausted = self._sender(max_attempts=2)
        err = OSError(32, "broken pipe")
        s._note_send_failure(0, err)
        s._note_send_failure(1, err)  # dest 1's first failure: no raise
        with pytest.raises(RetryExhausted):
            s._note_send_failure(0, err)
