"""The analysis suite's own tests: per-pass known-good/known-bad fixtures,
the three seeded regression fixtures from the repo's bug history (PR 5
publish-before-flush, PR 8 mtime staleness, PR 6 eager worker-path jax
import), suppression mechanics, and the suite run over the real src/ tree.
"""

import json
import os
import textwrap

import pytest

from repro.analysis import (
    ALL_PASSES, AnalysisConfig, AtomicPublishPass, Baseline,
    ImportHygienePass, LivenessClockPass, RetryDisciplinePass,
    SharedStateRacePass, ThreadLifecyclePass, WireSymmetryPass,
    collect_sources, run_analysis,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def write_tree(root, files: dict):
    """files: relpath -> dedented source text; returns collected Sources."""
    for rel, text in files.items():
        path = os.path.join(root, rel)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            f.write(textwrap.dedent(text))
    return collect_sources([root], root=root)


def run_pass(p, sources, **cfg):
    findings, _ = run_analysis(sources, config=AnalysisConfig(**cfg),
                               passes=[p])
    return findings


# -- thread-lifecycle ----------------------------------------------------------

GOOD_OWNER_THREAD = """
    import threading

    class Sender:
        def __init__(self):
            self._thread = threading.Thread(target=self._run, daemon=True)
            self._thread.start()

        def _run(self):
            pass

        def close(self):
            self._thread.join(timeout=10.0)
            self._check_stopped()

        def _check_stopped(self):
            if self._thread.is_alive():
                raise RuntimeError("thread leaked")
"""

GOOD_SCOPED_THREAD = """
    import threading

    def prefetch(items):
        t = threading.Thread(target=list, args=(items,), daemon=True)
        t.start()
        t.join(timeout=5.0)
        if t.is_alive():
            raise RuntimeError("prefetch thread leaked")
"""

BAD_NO_JOIN_THREAD = """
    import threading

    class Leaky:
        def start(self):
            self._thread = threading.Thread(target=self._run, daemon=True)
            self._thread.start()

        def _run(self):
            pass

        def close(self):
            self._closed = True  # never joins: the PR 6 leak class
"""


def test_thread_lifecycle_accepts_owner_and_scoped_idioms(tmp_path):
    srcs = write_tree(tmp_path, {"good_owner.py": GOOD_OWNER_THREAD,
                                 "good_scoped.py": GOOD_SCOPED_THREAD})
    assert run_pass(ThreadLifecyclePass(), srcs) == []


def test_thread_lifecycle_flags_joinless_close(tmp_path):
    srcs = write_tree(tmp_path, {"bad.py": BAD_NO_JOIN_THREAD})
    found = run_pass(ThreadLifecyclePass(), srcs)
    assert len(found) == 1
    assert found[0].scope == "Leaky.start"
    assert "join" in found[0].message


def test_thread_lifecycle_join_without_timeout_still_flags(tmp_path):
    srcs = write_tree(tmp_path, {"bad.py": """
        import threading

        class Hangable:
            def start(self):
                self._t = threading.Thread(target=self._run)
                self._t.start()

            def _run(self):
                pass

            def close(self):
                self._t.join()  # no timeout: close() can hang forever
                if self._t.is_alive():
                    raise RuntimeError
    """})
    assert len(run_pass(ThreadLifecyclePass(), srcs)) == 1


# -- liveness-clock ------------------------------------------------------------

# the PR 8 regression, reduced: staleness judged from file mtime
SEEDED_MTIME_STALENESS = """
    import os
    import time

    def is_stale(path, timeout):
        age = time.time() - os.stat(path).st_mtime
        return age > timeout
"""

GOOD_MONOTONIC = """
    import time

    def wait_with_deadline(cond, timeout):
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            if cond():
                return True
        return False
"""


def test_liveness_clock_flags_seeded_mtime_staleness(tmp_path):
    srcs = write_tree(tmp_path, {"bad.py": SEEDED_MTIME_STALENESS})
    found = run_pass(LivenessClockPass(), srcs)
    details = {f.detail for f in found}
    assert "time.time" in details and "st_mtime" in details


def test_liveness_clock_accepts_monotonic(tmp_path):
    srcs = write_tree(tmp_path, {"good.py": GOOD_MONOTONIC})
    assert run_pass(LivenessClockPass(), srcs) == []


def test_liveness_clock_flags_naive_datetime_and_getmtime(tmp_path):
    srcs = write_tree(tmp_path, {"bad.py": """
        import os.path
        from datetime import datetime

        def age(path):
            return datetime.now().timestamp() - os.path.getmtime(path)
    """})
    details = {f.detail for f in run_pass(LivenessClockPass(), srcs)}
    assert details == {"datetime", "getmtime"}


def test_allow_comment_suppresses_on_line_and_line_above(tmp_path):
    srcs = write_tree(tmp_path, {"ok.py": """
        import time

        def report():
            now = time.time()  # analysis: allow[liveness-clock] report only
            # analysis: allow[liveness-clock] report only
            then = time.time()
            return now, then
    """})
    open_f, suppressed = run_analysis(srcs, passes=[LivenessClockPass()])
    assert open_f == [] and len(suppressed) == 2


# -- atomic-publish ------------------------------------------------------------

# the PR 5 regression, reduced: the run counter publishes the extent
# before the bytes behind it are flushed
SEEDED_PUBLISH_BEFORE_FLUSH = """
    class Store:
        def append(self, dest, blob):
            fh = self._handle(dest)
            fh.write(blob)
            self._sizes[dest] += len(blob)  # reader can map garbage now
            fh.flush()
"""

GOOD_FLUSH_THEN_PUBLISH = """
    class Store:
        def append(self, dest, blob):
            fh = self._handle(dest)
            fh.write(blob)
            fh.flush()
            self._sizes[dest] += len(blob)
"""


def test_atomic_publish_flags_seeded_publish_before_flush(tmp_path):
    srcs = write_tree(tmp_path,
                      {"streams/msgstore.py": SEEDED_PUBLISH_BEFORE_FLUSH})
    found = run_pass(AtomicPublishPass(), srcs)
    assert len(found) == 1
    assert found[0].detail == "_sizes"
    assert found[0].scope == "Store.append"


def test_atomic_publish_accepts_flush_then_publish(tmp_path):
    srcs = write_tree(tmp_path,
                      {"streams/msgstore.py": GOOD_FLUSH_THEN_PUBLISH})
    assert run_pass(AtomicPublishPass(), srcs) == []


def test_atomic_publish_counter_rule_only_in_configured_modules(tmp_path):
    # same pattern outside counter_modules: the counter rule stays quiet
    srcs = write_tree(tmp_path,
                      {"other.py": SEEDED_PUBLISH_BEFORE_FLUSH})
    assert run_pass(AtomicPublishPass(), srcs) == []


def test_atomic_publish_flags_rename_without_fsync(tmp_path):
    srcs = write_tree(tmp_path, {"pub.py": """
        import json
        import os

        def publish(path, obj):
            tmp = path + ".tmp"
            with open(tmp, "w") as f:
                json.dump(obj, f)
            os.replace(tmp, path)
    """})
    found = run_pass(AtomicPublishPass(), srcs)
    assert [f.detail for f in found] == ["rename-fsync"]


def test_atomic_publish_flags_non_tmp_rename_source(tmp_path):
    srcs = write_tree(tmp_path, {"pub.py": """
        import os

        def clobber(a, b):
            os.fsync(0)
            os.replace(a, b)  # not published through a temp path
    """})
    found = run_pass(AtomicPublishPass(), srcs)
    assert [f.detail for f in found] == ["rename-source"]


def test_atomic_publish_accepts_tmp_fsync_replace(tmp_path):
    srcs = write_tree(tmp_path, {"pub.py": """
        import json
        import os

        def publish(path, obj):
            tmp = path + ".tmp"
            with open(tmp, "w") as f:
                json.dump(obj, f)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
    """})
    assert run_pass(AtomicPublishPass(), srcs) == []


# -- shared-state-race ---------------------------------------------------------

BAD_UNGUARDED_READ = """
    import threading

    class Worker:
        def __init__(self):
            self._exc = None
            self._t = threading.Thread(target=self._run)
            self._t.start()

        def _run(self):
            self._exc = RuntimeError("boom")

        def check(self):
            if self._exc is not None:
                raise self._exc

        def close(self):
            self._t.join(timeout=5.0)
            if self._t.is_alive():
                raise RuntimeError("leak")
"""


def test_race_flags_unguarded_cross_thread_read(tmp_path):
    srcs = write_tree(tmp_path, {"bad.py": BAD_UNGUARDED_READ})
    found = run_pass(SharedStateRacePass(), srcs)
    assert {f.detail for f in found} == {"_exc"}
    assert {f.scope for f in found} == {"Worker.check"}


def test_race_accepts_locked_fields_declaration(tmp_path):
    declared = BAD_UNGUARDED_READ.replace(
        "class Worker:",
        'class Worker:\n        _LOCKED_FIELDS = frozenset({"_exc"})')
    assert declared != BAD_UNGUARDED_READ
    srcs = write_tree(tmp_path, {"ok.py": declared})
    assert run_pass(SharedStateRacePass(), srcs) == []


def test_race_accepts_lock_guarded_read(tmp_path):
    srcs = write_tree(tmp_path, {"ok.py": """
        import threading

        class Worker:
            def __init__(self):
                self._lock = threading.Lock()
                self._n = 0
                self._t = threading.Thread(target=self._run)
                self._t.start()

            def _run(self):
                with self._lock:
                    self._n += 1

            def count(self):
                with self._lock:
                    return self._n

            def close(self):
                self._t.join(timeout=5.0)
                if self._t.is_alive():
                    raise RuntimeError("leak")
    """})
    assert run_pass(SharedStateRacePass(), srcs) == []


def test_race_sees_reads_through_private_helpers(tmp_path):
    # public check() -> private _raise() -> self._exc: still a public read
    indirect = BAD_UNGUARDED_READ.replace(
        """def check(self):
            if self._exc is not None:
                raise self._exc""",
        """def check(self):
            self._raise()

        def _raise(self):
            if self._exc is not None:
                raise self._exc""")
    assert indirect != BAD_UNGUARDED_READ
    srcs = write_tree(tmp_path, {"bad.py": indirect})
    found = run_pass(SharedStateRacePass(), srcs)
    assert {f.scope for f in found} == {"Worker._raise"}


# -- wire-symmetry -------------------------------------------------------------

def test_wire_flags_one_sided_struct(tmp_path):
    srcs = write_tree(tmp_path, {"codec.py": """
        import struct

        HEADER = struct.Struct(">IBII")

        def encode(a, b, c, d):
            return HEADER.pack(a, b, c, d)  # nothing ever unpacks HEADER
    """})
    found = run_pass(WireSymmetryPass(), srcs)
    assert [f.detail for f in found] == ["HEADER"]


def test_wire_accepts_symmetric_struct_and_literal_fmts(tmp_path):
    srcs = write_tree(tmp_path, {"codec.py": """
        import struct

        HEADER = struct.Struct(">IBII")

        def encode(a, b, c, d):
            return HEADER.pack(a, b, c, d) + struct.pack(">I", a)

        def decode(buf):
            return HEADER.unpack(buf[:13]), struct.unpack(">I", buf[13:17])
    """})
    assert run_pass(WireSymmetryPass(), srcs) == []


def test_wire_flags_decoder_key_the_encoder_never_writes(tmp_path):
    srcs = write_tree(tmp_path, {"codec.py": """
        import json

        def encode_run(step, seq):
            return json.dumps(dict(step=step, seq=seq)).encode()

        def decode_run(payload):
            hdr = json.loads(payload)
            return hdr["step"], hdr["seq"], hdr["tag"]  # tag never written
    """})
    found = run_pass(WireSymmetryPass(), srcs)
    assert [f.detail for f in found] == ["tag"]


def test_wire_decoder_keys_may_be_a_subset(tmp_path):
    srcs = write_tree(tmp_path, {"codec.py": """
        import json

        def encode_run(step, seq, tag):
            return json.dumps(dict(step=step, seq=seq, tag=tag)).encode()

        def decode_run(payload):
            hdr = json.loads(payload)
            return hdr["step"]  # envelope fields read elsewhere
    """})
    assert run_pass(WireSymmetryPass(), srcs) == []


# -- import-hygiene ------------------------------------------------------------

# the PR 6 regression, reduced: an eager jax import on the worker path —
# smuggled through a parent package __init__ the worker path executes
SEEDED_WORKER_JAX = {
    "repro/launch/procs.py": """
        from repro.streams.store import EdgeStore
    """,
    "repro/streams/__init__.py": """
        import jax  # eager: executed by ANY repro.streams.* import
    """,
    "repro/streams/store.py": """
        class EdgeStore:
            pass
    """,
}


def test_import_hygiene_flags_seeded_eager_jax_via_parent_init(tmp_path):
    srcs = write_tree(tmp_path, dict(SEEDED_WORKER_JAX))
    found = run_pass(ImportHygienePass(), srcs,
                     worker_roots=("repro.launch.procs",))
    assert len(found) == 1
    assert found[0].detail == "jax"
    assert "repro.streams" in found[0].message


def test_import_hygiene_accepts_lazy_function_level_import(tmp_path):
    files = dict(SEEDED_WORKER_JAX)
    files["repro/streams/__init__.py"] = """
        def _lazy():
            import jax  # inside a function: lazy, off the eager graph
            return jax
    """
    srcs = write_tree(tmp_path, files)
    assert run_pass(ImportHygienePass(), srcs,
                    worker_roots=("repro.launch.procs",)) == []


def test_import_hygiene_flags_direct_eager_import(tmp_path):
    srcs = write_tree(tmp_path, {"repro/launch/procs.py": """
        import jax
    """})
    found = run_pass(ImportHygienePass(), srcs,
                     worker_roots=("repro.launch.procs",))
    assert [f.detail for f in found] == ["jax"]


def test_import_hygiene_type_checking_imports_are_lazy(tmp_path):
    srcs = write_tree(tmp_path, {"repro/launch/procs.py": """
        from typing import TYPE_CHECKING

        if TYPE_CHECKING:
            import jax
    """})
    assert run_pass(ImportHygienePass(), srcs,
                    worker_roots=("repro.launch.procs",)) == []


# -- retry-discipline ----------------------------------------------------------

# the PR 10 regression shape, reduced: a dead peer spins this forever
SEEDED_BARE_RECONNECT = """
    import socket
    import time

    def reconnect(addr):
        while True:
            try:
                return socket.create_connection(addr, timeout=5.0)
            except OSError:
                time.sleep(0.1)
"""

GOOD_RETRY_ATTEMPTS = """
    import socket

    def reconnect(addr, retry):
        for attempt in retry.attempts("peer-reconnect"):
            try:
                return socket.create_connection(addr, timeout=5.0)
            except OSError:
                continue
"""


def test_retry_discipline_flags_seeded_bare_reconnect(tmp_path):
    srcs = write_tree(tmp_path, {"bad.py": SEEDED_BARE_RECONNECT})
    found = run_pass(RetryDisciplinePass(), srcs)
    assert [f.detail for f in found] == ["create_connection"]


def test_retry_discipline_accepts_attempts_generator(tmp_path):
    srcs = write_tree(tmp_path, {"good.py": GOOD_RETRY_ATTEMPTS})
    assert run_pass(RetryDisciplinePass(), srcs) == []


def test_retry_discipline_flags_bare_connect_and_accept_loops(tmp_path):
    srcs = write_tree(tmp_path, {"bad.py": """
        import socket

        def dial(sock, addr):
            while 1:
                try:
                    sock.connect(addr)
                    return
                except OSError:
                    pass

        def serve(listener):
            while True:
                conn, _ = listener.accept()
                handle(conn)
    """})
    details = sorted(f.detail for f in run_pass(RetryDisciplinePass(), srcs))
    assert details == ["accept", "connect"]


def test_retry_discipline_ignores_flag_gated_and_retry_bounded_loops(
        tmp_path):
    srcs = write_tree(tmp_path, {"good.py": """
        import socket

        class Server:
            def accept_loop(self):
                # gated on a close flag, not constant-true: never flagged
                while not self._closed:
                    conn, _ = self._sock.accept()

            def dial(self, addr):
                while True:
                    # a retry-policy reference inside the loop shows the
                    # bound lives here even without .attempts()
                    if self._retry.delay_for(self._n) is None:
                        raise ConnectionError(addr)
                    try:
                        return socket.create_connection(addr)
                    except OSError:
                        self._n += 1
    """})
    assert run_pass(RetryDisciplinePass(), srcs) == []


def test_retry_discipline_allow_comment_suppresses(tmp_path):
    srcs = write_tree(tmp_path, {"ok.py": """
        import socket

        def dial(addr, deadline_reached):
            while True:
                try:
                    return socket.create_connection(addr)  # analysis: allow[retry-discipline] outer deadline bounds this
                except OSError:
                    if deadline_reached():
                        raise
    """})
    open_f, suppressed = run_analysis(srcs, passes=[RetryDisciplinePass()])
    assert open_f == [] and len(suppressed) == 1


# -- suppression mechanics -----------------------------------------------------

def test_baseline_suppresses_by_stable_key_and_reports_unused(tmp_path):
    srcs = write_tree(tmp_path, {"bad.py": BAD_NO_JOIN_THREAD})
    (found,) = run_pass(ThreadLifecyclePass(), srcs)
    bl_path = os.path.join(tmp_path, "baseline.json")
    with open(bl_path, "w") as f:
        json.dump({"suppressions": [
            {"key": found.key, "reason": "reviewed: fixture"},
            {"key": "thread-lifecycle:gone.py:X.y:Thread",
             "reason": "stale entry"},
        ]}, f)
    bl = Baseline.load(bl_path)
    open_f, suppressed = run_analysis(srcs, passes=[ThreadLifecyclePass()],
                                      baseline=bl)
    assert open_f == [] and len(suppressed) == 1
    assert bl.unused(open_f + suppressed) == [
        "thread-lifecycle:gone.py:X.y:Thread"]


def test_baseline_rejects_entries_without_reason(tmp_path):
    bl_path = os.path.join(tmp_path, "baseline.json")
    with open(bl_path, "w") as f:
        json.dump({"suppressions": [{"key": "a:b:c:d"}]}, f)
    with pytest.raises(ValueError, match="reason"):
        Baseline.load(bl_path)


def test_finding_keys_are_line_independent(tmp_path):
    srcs1 = write_tree(tmp_path / "a", {"bad.py": BAD_NO_JOIN_THREAD})
    srcs2 = write_tree(tmp_path / "b",
                       {"bad.py": "# a new leading comment\n"
                        + textwrap.dedent(BAD_NO_JOIN_THREAD)})
    (f1,) = run_pass(ThreadLifecyclePass(), srcs1)
    (f2,) = run_pass(ThreadLifecyclePass(), srcs2)
    assert f1.key == f2.key
    assert f1.line != f2.line


# -- the CLI and the real tree -------------------------------------------------

def test_cli_json_output_and_exit_codes(tmp_path, capsys, monkeypatch):
    from repro.analysis.__main__ import main

    write_tree(tmp_path, {"bad.py": SEEDED_MTIME_STALENESS})
    monkeypatch.chdir(tmp_path)
    rc = main(["--json", str(tmp_path)])
    out = json.loads(capsys.readouterr().out)
    assert rc == 1
    assert {f["pass_id"] for f in out["open"]} == {"liveness-clock"}
    assert all(f["key"] for f in out["open"])


def test_repo_src_is_clean_under_committed_baseline():
    """The acceptance gate: the suite over src/ with the committed baseline
    has zero open findings. Every new finding is fixed, inline-allowed, or
    baselined with a review — this test is what makes that mechanical."""
    srcs = collect_sources([os.path.join(REPO, "src")], root=REPO)
    baseline = Baseline.load(os.path.join(REPO, "analysis-baseline.json"))
    open_f, _ = run_analysis(srcs, passes=list(ALL_PASSES),
                             baseline=baseline)
    assert open_f == [], "\n\n".join(f.render() for f in open_f)
