"""Out-of-core streamed execution (tentpole): the on-disk edge-block store,
the prefetching reader, cross-mode result equivalence, the O(|V|/n) memory
guarantee, skip()-driven I/O avoidance, and manifest-aware recovery."""

import collections

import numpy as np
import pytest

from repro.core import GraphDEngine, HashMin, PageRank, SSSP
from repro.core.checkpoint import Checkpointer
from repro.graph import (
    chain_graph, partition_graph, partition_graph_streamed, rmat_graph,
    spill_partition,
)
from repro.streams import EdgeStreamStore, StreamReader, plan_stream_schedule


@pytest.fixture
def spilled(tmp_path):
    g = rmat_graph(scale=7, edge_factor=8, seed=3)
    pg_full, _ = partition_graph(g, n_shards=4, edge_block=64)
    pg, rmap, store = partition_graph_streamed(
        g, 4, str(tmp_path / "spill"), edge_block=64
    )
    return g, pg_full, pg, rmap, store


# ---------------------------------------------------------------------------
# the store: on-disk layout == in-memory layout, open() roundtrip, skip()
# ---------------------------------------------------------------------------

class TestEdgeStreamStore:
    def test_spill_preserves_groups(self, spilled):
        _, pg_full, pg, _, store = spilled
        sp0 = np.asarray(pg_full.src_pos)
        dp0 = np.asarray(pg_full.dst_pos)
        w0 = np.asarray(pg_full.eweight)
        n, E_cap = pg_full.n_shards, pg_full.E_cap
        for i in range(n):
            for k in range(n):
                sp, dp, w = store.group_edges(i, k)
                assert np.array_equal(sp.reshape(-1), sp0[i, k])
                assert np.array_equal(dp.reshape(-1), dp0[i, k])
                assert np.array_equal(w.reshape(-1), w0[i, k])
        # and the vertex-only partition really dropped the O(|E|) arrays
        assert np.asarray(pg.src_pos).size == 0
        assert np.asarray(pg.blk_lo).size == 0

    def test_open_roundtrip(self, spilled, tmp_path):
        _, _, _, _, store = spilled
        reopened = EdgeStreamStore.open(store.dir)
        assert reopened.geom == store.geom
        assert reopened.signature() == store.signature()
        assert np.array_equal(reopened.blk_lo, store.blk_lo)
        assert np.array_equal(reopened.blk_hi, store.blk_hi)

    def test_block_manifest_matches_partition(self, spilled):
        _, pg_full, _, _, store = spilled
        assert np.array_equal(store.blk_lo, np.asarray(pg_full.blk_lo))
        assert np.array_equal(store.blk_hi, np.asarray(pg_full.blk_hi))

    def test_signature_covers_edge_content(self, tmp_path):
        """Equal topology + different weights must NOT look interchangeable
        to checkpoint recovery."""
        g1 = rmat_graph(scale=6, edge_factor=4, seed=2)
        g2 = rmat_graph(scale=6, edge_factor=4, seed=2, weights="uniform")
        assert np.array_equal(g1.src, g2.src)  # same topology
        _, _, s1 = partition_graph_streamed(g1, 2, str(tmp_path / "a"),
                                            edge_block=32)
        _, _, s2 = partition_graph_streamed(g2, 2, str(tmp_path / "b"),
                                            edge_block=32)
        assert s1.signature() != s2.signature()

    def test_skip_no_active_no_blocks(self, spilled):
        _, _, pg, _, store = spilled
        dead = np.zeros(pg.P, bool)
        prefix = np.concatenate([[0], np.cumsum(dead.astype(np.int64))])
        for i in range(4):
            for k in range(4):
                assert store.active_blocks(i, k, prefix).size == 0

    def test_skip_matches_block_ranges(self, spilled):
        _, _, pg, _, store = spilled
        rng = np.random.default_rng(0)
        active = rng.random(pg.P) < 0.2
        prefix = np.concatenate([[0], np.cumsum(active.astype(np.int64))])
        for i in range(4):
            for k in range(4):
                got = set(store.active_blocks(i, k, prefix).tolist())
                want = set()
                for b in range(store.geom.n_blocks):
                    lo, hi = store.blk_lo[i, k, b], store.blk_hi[i, k, b]
                    if hi >= 0 and active[lo:hi + 1].any():
                        want.add(b)
                assert got == want


class TestStreamReader:
    def test_chunks_cover_schedule_exactly(self, spilled):
        _, pg_full, pg, _, store = spilled
        active = np.ones((4, pg.P), bool)
        schedule, density, _ = plan_stream_schedule(store, active)
        assert density == 1.0
        reader = StreamReader(store, chunk_blocks=1, depth=2)
        seen = collections.Counter()
        edges = 0
        for chunk in reader.stream(schedule):
            seen[(chunk.src_shard, chunk.dst_shard)] += chunk.n_real_blocks
            edges += int((chunk.sp >= 0).sum())
        want = {
            (i, k): int(ids.size) for i, k, ids in schedule
        }
        assert dict(seen) == want
        assert edges == pg_full.n_edges
        assert reader.stats.blocks_read == sum(want.values())

    def test_partial_chunks_padded_neutral(self, spilled):
        _, _, pg, _, store = spilled
        active = np.ones((4, pg.P), bool)
        schedule, _, _ = plan_stream_schedule(store, active)
        # chunk_blocks larger than any group => every chunk is partial
        reader = StreamReader(store, chunk_blocks=16, depth=2)
        B = store.geom.edge_block
        for chunk in reader.stream(schedule):
            tail = chunk.sp[chunk.n_real_blocks * B:]
            assert (tail == -1).all()  # compute-neutral padding

    def test_staging_is_constant_sized(self, spilled):
        _, _, _, _, store = spilled
        r = StreamReader(store, chunk_blocks=4, depth=2)
        B = store.geom.edge_block
        assert r.staging_bytes() == 3 * (4 * B * 12)  # (depth+1) buffers


# ---------------------------------------------------------------------------
# cross-mode equivalence: streamed must agree with every in-memory mode
# ---------------------------------------------------------------------------

class TestCrossModeEquivalence:
    MODES = ["recoded", "basic", "basic_sc"]

    def _run_all(self, g, prog_factory, tmp_path, n=4, edge_block=64):
        pg, rmap = partition_graph(g, n_shards=n, edge_block=edge_block)
        pgs, _, store = partition_graph_streamed(
            g, n, str(tmp_path / "s"), edge_block=edge_block, recode=rmap
        )
        outs = {}
        for mode in self.MODES:
            eng = GraphDEngine(pg, prog_factory(rmap), mode=mode)
            (vals, _), _ = eng.run()
            outs[mode] = eng.gather_values(vals)
        eng = GraphDEngine(pgs, prog_factory(rmap), mode="streamed",
                           stream_store=store)
        (vals, _), _ = eng.run()
        outs["streamed"] = eng.gather_values(vals)
        return outs

    def test_pagerank(self, tmp_path):
        g = rmat_graph(scale=7, edge_factor=8, seed=3)
        outs = self._run_all(g, lambda _: PageRank(supersteps=6), tmp_path)
        ref = outs["recoded"]
        for mode, got in outs.items():
            # tolerance-aware: float accumulation order differs per mode
            err = max(abs(got[k] - ref[k]) for k in ref)
            assert err < 1e-6, (mode, err)

    def test_sssp(self, tmp_path):
        g = rmat_graph(scale=7, edge_factor=6, seed=5, weights="uniform")
        def mk(rmap):
            src = int(rmap.to_new(np.array([int(g.vertex_ids[0])]))[0])
            return SSSP(src)
        outs = self._run_all(g, mk, tmp_path)
        ref = outs["recoded"]
        for mode, got in outs.items():
            for k, v in ref.items():
                o = got[k]
                assert (np.isinf(v) and np.isinf(o)) or abs(o - v) < 1e-5, mode

    def test_hashmin(self, tmp_path):
        g = rmat_graph(scale=7, edge_factor=4, seed=11)
        outs = self._run_all(g, lambda _: HashMin(), tmp_path)
        ref = outs["recoded"]
        for mode, got in outs.items():
            assert got == ref, mode  # integer labels: bit-for-bit


# ---------------------------------------------------------------------------
# the memory guarantee: resident bytes are O(|V|/n), independent of |E|
# ---------------------------------------------------------------------------

class TestMemoryGuarantee:
    def _engines(self, edge_factor, tmp_path, tag):
        # |E| >> |V|: scale 8 => |V| <= 256, edge_factor up to 48 edges/vertex
        g = rmat_graph(scale=8, edge_factor=edge_factor, seed=7)
        pg, _ = partition_graph(g, n_shards=4, edge_block=32)
        pgs, _, store = partition_graph_streamed(
            g, 4, str(tmp_path / f"sp{tag}"), edge_block=32
        )
        mem = GraphDEngine(pg, PageRank(supersteps=2), mode="recoded")
        out = GraphDEngine(pgs, PageRank(supersteps=2), mode="streamed",
                           stream_store=store, stream_chunk_blocks=2)
        return g, mem, out

    @staticmethod
    def _ram(m):
        return m["resident"] + m["buffers"] + m["staging"]

    def test_resident_independent_of_E(self, tmp_path):
        g1, mem1, out1 = self._engines(4, tmp_path, "a")
        g2, mem2, out2 = self._engines(48, tmp_path, "b")
        assert g2.n_edges > 4 * g1.n_edges  # |E| really grew
        assert g2.n_vertices == g1.n_vertices
        s1, s2 = out1.memory_model(), out2.memory_model()
        # streamed RAM footprint: exactly equal despite >4x the edges
        assert self._ram(s1) == self._ram(s2)
        # ... while the on-disk tier grows with |E|
        assert s2["streamed"] > s1["streamed"]
        # ... and the in-memory engine's device edge bytes grow too
        m1, m2 = mem1.memory_model(), mem2.memory_model()
        assert m2["streamed"] > 4 * m1["streamed"]

    def test_resident_small_constant_of_V_over_n(self, tmp_path):
        g, mem, out = self._engines(48, tmp_path, "c")
        s = out.memory_model()
        pg = out.pg
        # per-shard vertex state: P slots, <= 32 B/slot across all arrays
        vertex_bytes = pg.P * 32
        # staging pool is a compiled-in constant: chunk_blocks * edge_block
        assert self._ram(s) <= 4 * vertex_bytes + out._stream_reader.staging_bytes()
        # and the in-memory engine holds edge-sized state the streamed one
        # does not: its device footprint exceeds the streamed RAM total
        m = mem.memory_model()
        assert self._ram(m) + m["streamed"] > self._ram(s)
        # the spilled partition itself holds no edge-sized arrays
        per_shard_resident = sum(
            np.asarray(a).nbytes
            for a in (pg.degree, pg.vmask, pg.old_ids, pg.gids)
        ) // pg.n_shards + np.asarray(pg.src_pos).nbytes
        assert per_shard_resident <= vertex_bytes


# ---------------------------------------------------------------------------
# skip() really avoids I/O + streamed fault tolerance
# ---------------------------------------------------------------------------

class TestStreamedExecution:
    def test_chain_sssp_reads_few_blocks(self, tmp_path):
        """On a chain with a 1-vertex frontier, skip() must keep per-step
        disk reads near-constant instead of scanning all blocks."""
        g = chain_graph(256)
        pgs, rmap, store = partition_graph_streamed(
            g, 4, str(tmp_path / "chain"), edge_block=8
        )
        src_new = int(rmap.to_new(np.array([0]))[0])
        eng = GraphDEngine(pgs, SSSP(src_new), mode="streamed",
                           stream_store=store, stream_chunk_blocks=2)
        blocks_per_step = []
        (vals, _), hist = eng.run(
            max_supersteps=300,
            on_step=lambda rec, s: blocks_per_step.append(
                eng._stream_reader.stats.blocks_read
            ),
        )
        got = eng.gather_values(vals)
        assert all(got[k] == k for k in got)  # dist(0 -> k) = k on the chain
        total = store.nonempty_blocks()
        # the frontier touches O(1) blocks per superstep; a full scan would
        # read `total` every time
        assert max(blocks_per_step[1:]) <= max(4, total // 4)

    def test_streamed_quiescence(self, tmp_path):
        g = chain_graph(32)
        pgs, rmap, store = partition_graph_streamed(
            g, 2, str(tmp_path / "q"), edge_block=8
        )
        src_new = int(rmap.to_new(np.array([31]))[0])  # sink: no out-edges
        eng = GraphDEngine(pgs, SSSP(src_new), mode="streamed",
                           stream_store=store)
        (_, _), hist = eng.run()
        assert len(hist) == 1  # immediately quiescent

    def test_checkpoint_restart_matches(self, spilled, tmp_path):
        _, _, pg, _, store = spilled
        (v_ref, _), _ = GraphDEngine(
            pg, PageRank(supersteps=8), mode="streamed", stream_store=store
        ).run()
        ck = Checkpointer(str(tmp_path / "ck"), every=3)
        eng = GraphDEngine(pg, PageRank(supersteps=8), mode="streamed",
                           stream_store=store)
        eng.run(max_supersteps=5, checkpointer=ck)  # "crash" after step 5
        eng2 = GraphDEngine(pg, PageRank(supersteps=8), mode="streamed",
                            stream_store=store)
        (v2, _), hist = eng2.run(checkpointer=ck)  # resumes from step 3
        assert hist[0].step == 3
        assert np.allclose(np.asarray(v2), np.asarray(v_ref))

    def test_manifest_mismatch_refused(self, spilled, tmp_path):
        """A checkpoint written against one edge stream must not silently
        restore against another (manifest-aware recovery)."""
        g, _, pg, _, store = spilled
        ck = Checkpointer(str(tmp_path / "ck2"), every=2)
        GraphDEngine(pg, PageRank(supersteps=4), mode="streamed",
                     stream_store=store).run(checkpointer=ck)
        g2 = rmat_graph(scale=7, edge_factor=4, seed=99)
        pg2, _, store2 = partition_graph_streamed(
            g2, 4, str(tmp_path / "other"), edge_block=64
        )
        with pytest.raises(ValueError, match="different edge streams"):
            ck.restore(expected_meta=store2.signature())

    def test_spilled_partition_rejected_by_in_memory_modes(self, spilled):
        """A vertex-only partition in mode='recoded' would silently compute
        a wrong fixpoint (no edges -> no messages); must raise instead."""
        _, _, pg, _, _ = spilled
        with pytest.raises(ValueError, match="vertex-only"):
            GraphDEngine(pg, PageRank(), mode="recoded")

    def test_density_semantics_match_in_memory(self, spilled):
        """rec.density means 'fraction of blocks active NEXT superstep' in
        every mode — histories must line up step for step."""
        g, pg_full, pg, rmap, store = spilled
        src_new = int(rmap.to_new(np.array([int(g.vertex_ids[0])]))[0])
        (_, _), h_mem = GraphDEngine(pg_full, SSSP(src_new), mode="recoded",
                                     adapt_threshold=-1).run()
        eng = GraphDEngine(pg, SSSP(src_new), mode="streamed",
                           stream_store=store)
        (_, _), h_st = eng.run()
        assert len(h_mem) == len(h_st)
        for a, b in zip(h_mem, h_st):
            assert abs(a.density - b.density) < 1e-6

    def test_engine_validates_geometry(self, spilled, tmp_path):
        g, _, _, _, store = spilled
        pg_other, _ = partition_graph(g, n_shards=2, edge_block=64)
        with pytest.raises(ValueError, match="geometry"):
            GraphDEngine(pg_other, PageRank(), mode="streamed",
                         stream_store=store)

    def test_requires_store_and_rejects_plain_log(self, spilled, tmp_path):
        from repro.core.algorithms import DistinctInLabels
        from repro.core.checkpoint import MessageLog

        _, _, pg, _, store = spilled
        with pytest.raises(ValueError, match="stream_store"):
            GraphDEngine(pg, PageRank(), mode="streamed")
        # combiner-less programs are first-class in streamed mode now (the
        # OMS disk tier, tests/test_msgstore.py); what IS rejected is a
        # dense MessageLog, which would materialize O(n²·P) buffers
        GraphDEngine(pg, DistinctInLabels(), mode="streamed",
                     stream_store=store)
        with pytest.raises(ValueError, match="RunFileMessageLog"):
            GraphDEngine(pg, PageRank(), mode="streamed", stream_store=store,
                         message_log=MessageLog(str(tmp_path / "ml")))

    def test_spill_partition_matches_streamed_ctor(self, tmp_path):
        """spill_partition on an existing pg == partition_graph_streamed."""
        g = rmat_graph(scale=6, edge_factor=6, seed=2)
        pg_full, _ = partition_graph(g, n_shards=3, edge_block=32)
        pg_v, store = spill_partition(pg_full, str(tmp_path / "sp"))
        eng = GraphDEngine(pg_v, PageRank(supersteps=4), mode="streamed",
                           stream_store=store)
        (v, _), _ = eng.run()
        (v_ref, _), _ = GraphDEngine(pg_full, PageRank(supersteps=4)).run()
        assert np.abs(np.asarray(v) - np.asarray(v_ref)).max() < 1e-6
