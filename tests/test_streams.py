"""Out-of-core streamed execution (tentpole): the on-disk edge-block store,
the prefetching reader, cross-mode result equivalence, the O(|V|/n) memory
guarantee, skip()-driven I/O avoidance, and manifest-aware recovery."""

import collections
import os

import numpy as np
import pytest

from repro.core import (
    ChannelConfig, EngineConfig, GraphDEngine, HashMin, PageRank, SSSP,
    StreamConfig,
)
from repro.core.checkpoint import Checkpointer
from repro.graph import (
    chain_graph, partition_graph, partition_graph_streamed, rmat_graph,
    spill_partition,
)
from repro.streams import EdgeStreamStore, StreamReader, plan_stream_schedule


@pytest.fixture
def spilled(tmp_path):
    g = rmat_graph(scale=7, edge_factor=8, seed=3)
    pg_full, _ = partition_graph(g, n_shards=4, edge_block=64)
    pg, rmap, store = partition_graph_streamed(
        g, 4, str(tmp_path / "spill"), edge_block=64
    )
    return g, pg_full, pg, rmap, store


# ---------------------------------------------------------------------------
# the store: on-disk layout == in-memory layout, open() roundtrip, skip()
# ---------------------------------------------------------------------------

class TestEdgeStreamStore:
    def test_spill_preserves_groups(self, spilled):
        _, pg_full, pg, _, store = spilled
        sp0 = np.asarray(pg_full.src_pos)
        dp0 = np.asarray(pg_full.dst_pos)
        w0 = np.asarray(pg_full.eweight)
        n, E_cap = pg_full.n_shards, pg_full.E_cap
        for i in range(n):
            for k in range(n):
                sp, dp, w = store.group_edges(i, k)
                assert np.array_equal(sp.reshape(-1), sp0[i, k])
                assert np.array_equal(dp.reshape(-1), dp0[i, k])
                assert np.array_equal(w.reshape(-1), w0[i, k])
        # and the vertex-only partition really dropped the O(|E|) arrays
        assert np.asarray(pg.src_pos).size == 0
        assert np.asarray(pg.blk_lo).size == 0

    def test_open_roundtrip(self, spilled, tmp_path):
        _, _, _, _, store = spilled
        reopened = EdgeStreamStore.open(store.dir)
        assert reopened.geom == store.geom
        assert reopened.signature() == store.signature()
        assert np.array_equal(reopened.blk_lo, store.blk_lo)
        assert np.array_equal(reopened.blk_hi, store.blk_hi)

    def test_block_manifest_matches_partition(self, spilled):
        _, pg_full, _, _, store = spilled
        assert np.array_equal(store.blk_lo, np.asarray(pg_full.blk_lo))
        assert np.array_equal(store.blk_hi, np.asarray(pg_full.blk_hi))

    def test_signature_covers_edge_content(self, tmp_path):
        """Equal topology + different weights must NOT look interchangeable
        to checkpoint recovery."""
        g1 = rmat_graph(scale=6, edge_factor=4, seed=2)
        g2 = rmat_graph(scale=6, edge_factor=4, seed=2, weights="uniform")
        assert np.array_equal(g1.src, g2.src)  # same topology
        _, _, s1 = partition_graph_streamed(g1, 2, str(tmp_path / "a"),
                                            edge_block=32)
        _, _, s2 = partition_graph_streamed(g2, 2, str(tmp_path / "b"),
                                            edge_block=32)
        assert s1.signature() != s2.signature()

    def test_skip_no_active_no_blocks(self, spilled):
        _, _, pg, _, store = spilled
        dead = np.zeros(pg.P, bool)
        prefix = np.concatenate([[0], np.cumsum(dead.astype(np.int64))])
        for i in range(4):
            for k in range(4):
                assert store.active_blocks(i, k, prefix).size == 0

    def test_skip_matches_block_ranges(self, spilled):
        _, _, pg, _, store = spilled
        rng = np.random.default_rng(0)
        active = rng.random(pg.P) < 0.2
        prefix = np.concatenate([[0], np.cumsum(active.astype(np.int64))])
        for i in range(4):
            for k in range(4):
                got = set(store.active_blocks(i, k, prefix).tolist())
                want = set()
                for b in range(store.geom.n_blocks):
                    lo, hi = store.blk_lo[i, k, b], store.blk_hi[i, k, b]
                    if hi >= 0 and active[lo:hi + 1].any():
                        want.add(b)
                assert got == want


class TestStreamReader:
    def test_chunks_cover_schedule_exactly(self, spilled):
        _, pg_full, pg, _, store = spilled
        active = np.ones((4, pg.P), bool)
        schedule, density, _ = plan_stream_schedule(store, active)
        assert density == 1.0
        reader = StreamReader(store, chunk_blocks=1, depth=2)
        seen = collections.Counter()
        edges = 0
        for chunk in reader.stream(schedule):
            seen[(chunk.src_shard, chunk.dst_shard)] += chunk.n_real_blocks
            edges += int((chunk.sp >= 0).sum())
        want = {
            (i, k): int(ids.size) for i, k, ids in schedule
        }
        assert dict(seen) == want
        assert edges == pg_full.n_edges
        assert reader.stats.blocks_read == sum(want.values())

    def test_partial_chunks_padded_neutral(self, spilled):
        _, _, pg, _, store = spilled
        active = np.ones((4, pg.P), bool)
        schedule, _, _ = plan_stream_schedule(store, active)
        # chunk_blocks larger than any group => every chunk is partial
        reader = StreamReader(store, chunk_blocks=16, depth=2)
        B = store.geom.edge_block
        for chunk in reader.stream(schedule):
            tail = chunk.sp[chunk.n_real_blocks * B:]
            assert (tail == -1).all()  # compute-neutral padding

    def test_staging_is_constant_sized(self, spilled):
        _, _, _, _, store = spilled
        r = StreamReader(store, chunk_blocks=4, depth=2)
        B = store.geom.edge_block
        assert r.staging_bytes() == 3 * (4 * B * 12)  # (depth+1) buffers


# ---------------------------------------------------------------------------
# cross-mode equivalence: streamed must agree with every in-memory mode
# ---------------------------------------------------------------------------

class TestCrossModeEquivalence:
    MODES = ["recoded", "basic", "basic_sc"]

    def _run_all(self, g, prog_factory, tmp_path, n=4, edge_block=64):
        pg, rmap = partition_graph(g, n_shards=n, edge_block=edge_block)
        pgs, _, store = partition_graph_streamed(
            g, n, str(tmp_path / "s"), edge_block=edge_block, recode=rmap
        )
        outs = {}
        for mode in self.MODES:
            eng = GraphDEngine(
                      pg,
                      prog_factory(rmap),
                      config=EngineConfig(mode=mode),
                  )
            (vals, _), _ = eng.run()
            outs[mode] = eng.gather_values(vals)
        eng = GraphDEngine(
                  pgs,
                  prog_factory(rmap),
                  config=EngineConfig(mode="streamed"),
                  stream_store=store,
              )
        (vals, _), _ = eng.run()
        outs["streamed"] = eng.gather_values(vals)
        return outs

    def test_pagerank(self, tmp_path):
        g = rmat_graph(scale=7, edge_factor=8, seed=3)
        outs = self._run_all(g, lambda _: PageRank(supersteps=6), tmp_path)
        ref = outs["recoded"]
        for mode, got in outs.items():
            # tolerance-aware: float accumulation order differs per mode
            err = max(abs(got[k] - ref[k]) for k in ref)
            assert err < 1e-6, (mode, err)

    def test_sssp(self, tmp_path):
        g = rmat_graph(scale=7, edge_factor=6, seed=5, weights="uniform")
        def mk(rmap):
            src = int(rmap.to_new(np.array([int(g.vertex_ids[0])]))[0])
            return SSSP(src)
        outs = self._run_all(g, mk, tmp_path)
        ref = outs["recoded"]
        for mode, got in outs.items():
            for k, v in ref.items():
                o = got[k]
                assert (np.isinf(v) and np.isinf(o)) or abs(o - v) < 1e-5, mode

    def test_hashmin(self, tmp_path):
        g = rmat_graph(scale=7, edge_factor=4, seed=11)
        outs = self._run_all(g, lambda _: HashMin(), tmp_path)
        ref = outs["recoded"]
        for mode, got in outs.items():
            assert got == ref, mode  # integer labels: bit-for-bit


# ---------------------------------------------------------------------------
# the memory guarantee: resident bytes are O(|V|/n), independent of |E|
# ---------------------------------------------------------------------------

class TestMemoryGuarantee:
    def _engines(self, edge_factor, tmp_path, tag):
        # |E| >> |V|: scale 8 => |V| <= 256, edge_factor up to 48 edges/vertex
        g = rmat_graph(scale=8, edge_factor=edge_factor, seed=7)
        pg, _ = partition_graph(g, n_shards=4, edge_block=32)
        pgs, _, store = partition_graph_streamed(
            g, 4, str(tmp_path / f"sp{tag}"), edge_block=32
        )
        mem = GraphDEngine(
                  pg,
                  PageRank(supersteps=2),
                  config=EngineConfig(mode="recoded"),
              )
        out = GraphDEngine(
                  pgs,
                  PageRank(supersteps=2),
                  config=EngineConfig(mode="streamed", stream=StreamConfig(chunk_blocks=2)),
                  stream_store=store,
              )
        return g, mem, out

    @staticmethod
    def _ram(m):
        return m["resident"] + m["buffers"] + m["staging"]

    def test_resident_independent_of_E(self, tmp_path):
        g1, mem1, out1 = self._engines(4, tmp_path, "a")
        g2, mem2, out2 = self._engines(48, tmp_path, "b")
        assert g2.n_edges > 4 * g1.n_edges  # |E| really grew
        assert g2.n_vertices == g1.n_vertices
        s1, s2 = out1.memory_model(), out2.memory_model()
        # streamed RAM footprint: exactly equal despite >4x the edges
        assert self._ram(s1) == self._ram(s2)
        # ... while the on-disk tier grows with |E|
        assert s2["streamed"] > s1["streamed"]
        # ... and the in-memory engine's device edge bytes grow too
        m1, m2 = mem1.memory_model(), mem2.memory_model()
        assert m2["streamed"] > 4 * m1["streamed"]

    def test_resident_small_constant_of_V_over_n(self, tmp_path):
        g, mem, out = self._engines(48, tmp_path, "c")
        s = out.memory_model()
        pg = out.pg
        # per-shard vertex state: P slots, <= 32 B/slot across all arrays
        vertex_bytes = pg.P * 32
        # staging pool is a compiled-in constant: chunk_blocks * edge_block
        assert self._ram(s) <= 4 * vertex_bytes + out._stream_reader.staging_bytes()
        # and the in-memory engine holds edge-sized state the streamed one
        # does not: its device footprint exceeds the streamed RAM total
        m = mem.memory_model()
        assert self._ram(m) + m["streamed"] > self._ram(s)
        # the spilled partition itself holds no edge-sized arrays
        per_shard_resident = sum(
            np.asarray(a).nbytes
            for a in (pg.degree, pg.vmask, pg.old_ids, pg.gids)
        ) // pg.n_shards + np.asarray(pg.src_pos).nbytes
        assert per_shard_resident <= vertex_bytes


# ---------------------------------------------------------------------------
# skip() really avoids I/O + streamed fault tolerance
# ---------------------------------------------------------------------------

class TestStreamedExecution:
    def test_chain_sssp_reads_few_blocks(self, tmp_path):
        """On a chain with a 1-vertex frontier, skip() must keep per-step
        disk reads near-constant instead of scanning all blocks."""
        g = chain_graph(256)
        pgs, rmap, store = partition_graph_streamed(
            g, 4, str(tmp_path / "chain"), edge_block=8
        )
        src_new = int(rmap.to_new(np.array([0]))[0])
        eng = GraphDEngine(
                  pgs,
                  SSSP(src_new),
                  config=EngineConfig(mode="streamed", stream=StreamConfig(chunk_blocks=2)),
                  stream_store=store,
              )
        blocks_per_step = []
        (vals, _), hist = eng.run(
            max_supersteps=300,
            on_step=lambda rec, s: blocks_per_step.append(
                eng._stream_reader.stats.blocks_read
            ),
        )
        got = eng.gather_values(vals)
        assert all(got[k] == k for k in got)  # dist(0 -> k) = k on the chain
        total = store.nonempty_blocks()
        # the frontier touches O(1) blocks per superstep; a full scan would
        # read `total` every time
        assert max(blocks_per_step[1:]) <= max(4, total // 4)

    def test_streamed_quiescence(self, tmp_path):
        g = chain_graph(32)
        pgs, rmap, store = partition_graph_streamed(
            g, 2, str(tmp_path / "q"), edge_block=8
        )
        src_new = int(rmap.to_new(np.array([31]))[0])  # sink: no out-edges
        eng = GraphDEngine(
                  pgs,
                  SSSP(src_new),
                  config=EngineConfig(mode="streamed"),
                  stream_store=store,
              )
        (_, _), hist = eng.run()
        assert len(hist) == 1  # immediately quiescent

    def test_checkpoint_restart_matches(self, spilled, tmp_path):
        _, _, pg, _, store = spilled
        (v_ref, _), _ = GraphDEngine(
                            pg,
                            PageRank(supersteps=8),
                            config=EngineConfig(mode="streamed"),
                            stream_store=store,
                        ).run()
        ck = Checkpointer(str(tmp_path / "ck"), every=3)
        eng = GraphDEngine(
                  pg,
                  PageRank(supersteps=8),
                  config=EngineConfig(mode="streamed"),
                  stream_store=store,
              )
        eng.run(max_supersteps=5, checkpointer=ck)  # "crash" after step 5
        eng2 = GraphDEngine(
                   pg,
                   PageRank(supersteps=8),
                   config=EngineConfig(mode="streamed"),
                   stream_store=store,
               )
        (v2, _), hist = eng2.run(checkpointer=ck)  # resumes from step 3
        assert hist[0].step == 3
        assert np.allclose(np.asarray(v2), np.asarray(v_ref))

    def test_manifest_mismatch_refused(self, spilled, tmp_path):
        """A checkpoint written against one edge stream must not silently
        restore against another (manifest-aware recovery)."""
        g, _, pg, _, store = spilled
        ck = Checkpointer(str(tmp_path / "ck2"), every=2)
        GraphDEngine(
            pg,
            PageRank(supersteps=4),
            config=EngineConfig(mode="streamed"),
            stream_store=store,
        ).run(checkpointer=ck)
        g2 = rmat_graph(scale=7, edge_factor=4, seed=99)
        pg2, _, store2 = partition_graph_streamed(
            g2, 4, str(tmp_path / "other"), edge_block=64
        )
        with pytest.raises(ValueError, match="different edge streams"):
            ck.restore(expected_meta=store2.signature())

    def test_spilled_partition_rejected_by_in_memory_modes(self, spilled):
        """A vertex-only partition in mode='recoded' would silently compute
        a wrong fixpoint (no edges -> no messages); must raise instead."""
        _, _, pg, _, _ = spilled
        with pytest.raises(ValueError, match="vertex-only"):
            GraphDEngine(pg, PageRank(), config=EngineConfig(mode="recoded"))

    def test_density_semantics_match_in_memory(self, spilled):
        """rec.density means 'fraction of blocks active NEXT superstep' in
        every mode — histories must line up step for step."""
        g, pg_full, pg, rmap, store = spilled
        src_new = int(rmap.to_new(np.array([int(g.vertex_ids[0])]))[0])
        (_, _), h_mem = GraphDEngine(
                            pg_full,
                            SSSP(src_new),
                            config=EngineConfig(mode="recoded", adapt_threshold=-1),
                        ).run()
        eng = GraphDEngine(
                  pg,
                  SSSP(src_new),
                  config=EngineConfig(mode="streamed"),
                  stream_store=store,
              )
        (_, _), h_st = eng.run()
        assert len(h_mem) == len(h_st)
        for a, b in zip(h_mem, h_st):
            assert abs(a.density - b.density) < 1e-6

    def test_engine_validates_geometry(self, spilled, tmp_path):
        g, _, _, _, store = spilled
        pg_other, _ = partition_graph(g, n_shards=2, edge_block=64)
        with pytest.raises(ValueError, match="geometry"):
            GraphDEngine(
                pg_other,
                PageRank(),
                config=EngineConfig(mode="streamed"),
                stream_store=store,
            )

    def test_requires_store_and_rejects_plain_log(self, spilled, tmp_path):
        from repro.core.algorithms import DistinctInLabels
        from repro.core.checkpoint import MessageLog

        _, _, pg, _, store = spilled
        with pytest.raises(ValueError, match="stream_store"):
            GraphDEngine(pg, PageRank(), config=EngineConfig(mode="streamed"))
        # combiner-less programs are first-class in streamed mode now (the
        # OMS disk tier, tests/test_msgstore.py); what IS rejected is a
        # dense MessageLog, which would materialize O(n²·P) buffers
        GraphDEngine(
            pg,
            DistinctInLabels(),
            config=EngineConfig(mode="streamed"),
            stream_store=store,
        )
        with pytest.raises(ValueError, match="RunFileMessageLog"):
            GraphDEngine(
                pg,
                PageRank(),
                config=EngineConfig(mode="streamed"),
                stream_store=store,
                message_log=MessageLog(str(tmp_path / "ml")),
            )

    def test_spill_partition_matches_streamed_ctor(self, tmp_path):
        """spill_partition on an existing pg == partition_graph_streamed."""
        g = rmat_graph(scale=6, edge_factor=6, seed=2)
        pg_full, _ = partition_graph(g, n_shards=3, edge_block=32)
        pg_v, store = spill_partition(pg_full, str(tmp_path / "sp"))
        eng = GraphDEngine(
                  pg_v,
                  PageRank(supersteps=4),
                  config=EngineConfig(mode="streamed"),
                  stream_store=store,
              )
        (v, _), _ = eng.run()
        (v_ref, _), _ = GraphDEngine(pg_full, PageRank(supersteps=4)).run()
        assert np.abs(np.asarray(v) - np.asarray(v_ref)).max() < 1e-6


# ---------------------------------------------------------------------------
# manifest-driven row ownership (multi-process stepping stone) + compressed
# edge streams (ISSUE 3 tentpole)
# ---------------------------------------------------------------------------

class TestRowOwnership:
    def test_owner_view_serves_only_its_row(self, spilled):
        _, _, _, _, store = spilled
        view = store.owner_view(2)
        a = store.group_edges(2, 1)
        b = view.group_edges(2, 1)
        assert all(np.array_equal(x, y) for x, y in zip(a, b))
        with pytest.raises(PermissionError, match="owns only"):
            view.group_edges(0, 1)
        B = store.geom.edge_block
        bufs = (np.empty((1, B), np.int32), np.empty((1, B), np.int32),
                np.empty((1, B), np.float32))
        with pytest.raises(PermissionError, match="owns only"):
            view.read_blocks(1, 0, np.array([0]), *bufs)

    def test_open_with_owner_uses_manifest(self, spilled):
        """A machine opens its row straight from the published manifest —
        no full-store instance required (the multi-process access path)."""
        import json

        _, _, _, _, store = spilled
        with open(os.path.join(store.dir, "manifest.json")) as f:
            m = json.load(f)
        assert m["row_ownership"]["axis"] == "src_shard"
        rb = m["row_ownership"]["row_bytes"]
        assert all(len(v) == store.geom.n_shards + 1 for v in rb.values())
        view = EdgeStreamStore.open(store.dir, owner=1)
        assert view.owner == 1
        a = store.group_edges(1, 3)
        b = view.group_edges(1, 3)
        assert all(np.array_equal(x, y) for x, y in zip(a, b))

    def test_pipelined_engine_reads_through_owner_views(self, spilled):
        _, _, pg, _, store = spilled
        eng = GraphDEngine(
                  pg,
                  PageRank(supersteps=2),
                  config=EngineConfig(mode="streamed", channel=ChannelConfig(pipeline=True)),
                  stream_store=store,
              )
        eng.run()
        views = eng._stream_reader._views
        assert views is not None and views  # per-source views were used
        assert all(v.owner == i for i, v in views.items())

    def test_reader_owner_views_cover_schedule(self, spilled):
        _, pg_full, pg, _, store = spilled
        active = np.ones((4, pg.P), bool)
        schedule, _, _ = plan_stream_schedule(store, active)
        reader = StreamReader(store, chunk_blocks=2, owner_views=True)
        edges = sum(int((c.sp >= 0).sum()) for c in reader.stream(schedule))
        assert edges == pg_full.n_edges


class TestCompressedEdgeStore:
    def test_compressed_spill_same_content_smaller_disk(self, tmp_path):
        g = rmat_graph(scale=7, edge_factor=8, seed=3)
        pg, rmap = partition_graph(g, n_shards=4, edge_block=64)
        _, _, plain = partition_graph_streamed(
            g, 4, str(tmp_path / "p"), edge_block=64, recode=rmap
        )
        _, _, comp = partition_graph_streamed(
            g, 4, str(tmp_path / "c"), edge_block=64, recode=rmap,
            compress=True,
        )
        assert comp.disk_bytes() < plain.disk_bytes()
        # identical logical content => identical recovery signature
        assert comp.signature() == plain.signature()
        for i in range(4):
            for k in range(4):
                a, b = plain.group_edges(i, k), comp.group_edges(i, k)
                assert all(np.array_equal(x.reshape(-1), y.reshape(-1))
                           for x, y in zip(a, b))

    def test_compressed_open_roundtrip_and_owner_view(self, tmp_path):
        g = rmat_graph(scale=6, edge_factor=6, seed=2)
        _, _, store = partition_graph_streamed(
            g, 3, str(tmp_path / "c"), edge_block=32, compress=True
        )
        re = EdgeStreamStore.open(store.dir)
        assert re.compress
        view = EdgeStreamStore.open(store.dir, owner=2)
        a = store.group_edges(2, 0)
        b = view.group_edges(2, 0)
        assert all(np.array_equal(x, y) for x, y in zip(a, b))
        with pytest.raises(PermissionError):
            view.group_edges(1, 0)

    def test_streamed_over_compressed_store_bitmatches(self, tmp_path):
        g = rmat_graph(scale=7, edge_factor=6, seed=5)
        pg_full, rmap = partition_graph(g, n_shards=4, edge_block=64)
        pgs, _, store = partition_graph_streamed(
            g, 4, str(tmp_path / "c"), edge_block=64, recode=rmap,
            compress=True,
        )
        (v_ref, _), _ = GraphDEngine(
                            pg_full,
                            HashMin(),
                            config=EngineConfig(mode="basic"),
                        ).run()
        (v, _), _ = GraphDEngine(
                        pgs,
                        HashMin(),
                        config=EngineConfig(mode="streamed"),
                        stream_store=store,
                    ).run()
        assert np.array_equal(np.asarray(v), np.asarray(v_ref))


class TestPipelinedMemoryModel:
    def test_channel_budget_constant_and_ram_flat(self, tmp_path):
        """The in-flight channel budget is a compiled-in constant: the
        pipelined RAM total must not move as |E| grows (Theorem 1 still
        holds with the §4 overlap enabled)."""
        rams = []
        for tag, ef in (("a", 4), ("b", 48)):
            g = rmat_graph(scale=8, edge_factor=ef, seed=7)
            pgs, _, store = partition_graph_streamed(
                g, 4, str(tmp_path / f"sp{tag}"), edge_block=32
            )
            eng = GraphDEngine(
                      pgs,
                      PageRank(supersteps=2),
                      config=EngineConfig(mode="streamed", stream=StreamConfig(chunk_blocks=2), channel=ChannelConfig(pipeline=True)),
                      stream_store=store,
                  )
            m = eng.memory_model()
            assert m["channel"] == eng.channel_inflight * pgs.P * (4 + 4 + 4)
            rams.append(m["resident"] + m["buffers"] + m["staging"]
                        + m["channel"])
        assert rams[0] == rams[1]


class TestPayloadCompressedEdgeStore:
    """compress_payload= on the weight channel (PR 5): per-block payload
    blobs, same logical content, smaller disk, owner views intact."""

    def test_payload_spill_same_content_smaller_disk(self, tmp_path):
        g = rmat_graph(scale=7, edge_factor=8, seed=3, weights="uniform")
        pg, rmap = partition_graph(g, n_shards=4, edge_block=64)
        _, _, comp = partition_graph_streamed(
            g, 4, str(tmp_path / "c"), edge_block=64, recode=rmap,
            compress=True,
        )
        _, _, full = partition_graph_streamed(
            g, 4, str(tmp_path / "cp"), edge_block=64, recode=rmap,
            compress=True, compress_payload=True,
        )
        assert full.disk_bytes() < comp.disk_bytes()
        # identical logical content => identical recovery signature
        assert full.signature() == comp.signature()
        for i in range(4):
            for k in range(4):
                a, b = comp.group_edges(i, k), full.group_edges(i, k)
                assert all(np.array_equal(x.reshape(-1), y.reshape(-1))
                           for x, y in zip(a, b))

    def test_payload_open_roundtrip_and_owner_view(self, tmp_path):
        g = rmat_graph(scale=6, edge_factor=6, seed=2, weights="uniform")
        _, _, store = partition_graph_streamed(
            g, 3, str(tmp_path / "cp"), edge_block=32, compress=True,
            compress_payload=True,
        )
        re = EdgeStreamStore.open(store.dir)
        assert re.compress and re.compress_payload
        view = EdgeStreamStore.open(store.dir, owner=2)
        a = store.group_edges(2, 0)
        b = view.group_edges(2, 0)
        assert all(np.array_equal(x, y) for x, y in zip(a, b))
        with pytest.raises(PermissionError):
            view.group_edges(1, 0)

    def test_streamed_over_payload_store_bitmatches(self, tmp_path):
        g = rmat_graph(scale=7, edge_factor=6, seed=5, weights="uniform")
        pg_full, rmap = partition_graph(g, n_shards=4, edge_block=64)
        pgs, _, store = partition_graph_streamed(
            g, 4, str(tmp_path / "cp"), edge_block=64, recode=rmap,
            compress=True, compress_payload=True,
        )
        (v_ref, _), _ = GraphDEngine(
                            pg_full,
                            SSSP(0),
                            config=EngineConfig(mode="basic"),
                        ).run()
        (v, _), _ = GraphDEngine(
                        pgs,
                        SSSP(0),
                        config=EngineConfig(mode="streamed"),
                        stream_store=store,
                    ).run()
        assert np.array_equal(np.asarray(v), np.asarray(v_ref))
