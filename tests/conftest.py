# NOTE: no XLA_FLAGS / device-count manipulation here — smoke tests and
# benches must see 1 device (dry-run isolation rule). Multi-device tests
# spawn subprocesses with their own XLA_FLAGS.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
