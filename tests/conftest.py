# NOTE: no XLA_FLAGS / device-count manipulation here — smoke tests and
# benches must see 1 device (dry-run isolation rule). Multi-device tests
# spawn subprocesses with their own XLA_FLAGS.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# ---------------------------------------------------------------------------
# optional-dependency guard: property-based modules need `hypothesis`
# (requirements-dev.txt). When it is absent the modules below are skipped at
# collection (they also self-guard with pytest.importorskip, which reports a
# visible skip instead of a collection error), so `pytest -x -q` stays green
# on a bare interpreter.
# ---------------------------------------------------------------------------

PROPERTY_MODULES = ["test_properties.py"]

try:
    import hypothesis  # noqa: F401
except ImportError:
    collect_ignore = list(PROPERTY_MODULES)
