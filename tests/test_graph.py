"""Graph substrate tests: recoding, partitioning, block metadata (+ Lemma 1)."""

import collections

import numpy as np
import pytest

from repro.graph import (
    Graph, chain_graph, erdos_renyi_graph, partition_graph, recode_ids,
    rmat_graph, star_graph,
)
from repro.graph.recode import recode_distributed


class TestRecode:
    def test_bijection_fixed_ids(self):
        rng = np.random.default_rng(0)
        ids = np.unique(rng.integers(0, 200, size=300))
        for n in [1, 3, 9]:
            rmap = recode_ids(ids, n)
            new = rmap.to_new(ids)
            # bijective, shard-consistent, position-consistent
            assert len(set(new.tolist())) == len(ids)
            assert np.array_equal(rmap.to_old(new), ids)
            for g in new:
                assert 0 <= g < n * rmap.max_positions

    def test_distributed_recoding_matches_fast_path(self):
        """Paper §5: the 3-superstep recoding job produces the same streams."""
        rng = np.random.default_rng(1)
        src = rng.integers(0, 200, size=400).astype(np.int64)
        dst = rng.integers(0, 200, size=400).astype(np.int64)
        ids = np.unique(np.concatenate([src, dst]))
        for n in [1, 4, 6]:
            s1, d1, rmap = recode_distributed(src, dst, ids, n)
            assert np.array_equal(s1, rmap.to_new(src))
            assert np.array_equal(d1, rmap.to_new(dst))

    def test_sparse_ids(self):
        g = rmat_graph(scale=7, edge_factor=4, seed=1, sparse_ids=True)
        rmap = recode_ids(g.vertex_ids, 4)
        assert np.array_equal(rmap.to_old(rmap.to_new(g.vertex_ids)),
                              g.vertex_ids)


class TestLemma1:
    """Lemma 1: max shard size < 2|V|/n with high probability."""

    @pytest.mark.parametrize("n", [2, 4, 8, 16])
    def test_balance_bound(self, n):
        g = rmat_graph(scale=12, edge_factor=2, seed=7, sparse_ids=True)
        rmap = recode_ids(g.vertex_ids, n)
        V = rmap.n_vertices
        assert rmap.max_positions < 2 * V / n, (
            f"hash partitioning violated Lemma 1: {rmap.max_positions} "
            f">= 2*{V}/{n}"
        )

    @pytest.mark.parametrize("n", [2, 5, 12])
    def test_balance_random_ids(self, n):
        rng = np.random.default_rng(n)
        ids = np.unique(rng.integers(0, 2**48, size=5000))
        rmap = recode_ids(ids, n)
        assert rmap.max_positions < 2 * len(ids) / n


class TestPartition:
    def _check(self, g: Graph, n, edge_block):
        pg, rmap = partition_graph(g, n_shards=n, edge_block=edge_block)
        src_new, dst_new = rmap.to_new(g.src), rmap.to_new(g.dst)
        want = collections.Counter(
            zip((src_new % n).tolist(), (dst_new % n).tolist(),
                (src_new // n).tolist(), (dst_new // n).tolist())
        )
        sp, dp = np.asarray(pg.src_pos), np.asarray(pg.dst_pos)
        got = collections.Counter()
        for i in range(n):
            for k in range(n):
                m = sp[i, k] >= 0
                for s, d in zip(sp[i, k][m].tolist(), dp[i, k][m].tolist()):
                    got[(i, k, s, d)] += 1
        assert want == got  # every edge exactly once, correct positions
        assert np.asarray(pg.degree).sum() == g.n_edges
        # groups sorted by src (required by skip())
        for i in range(n):
            for k in range(n):
                v = sp[i, k][sp[i, k] >= 0]
                assert np.all(np.diff(v) >= 0)
        # block metadata covers exactly the real src ranges
        lo, hi = np.asarray(pg.blk_lo), np.asarray(pg.blk_hi)
        spb = sp.reshape(n, n, pg.n_blocks, pg.edge_block)
        for i in range(n):
            for k in range(n):
                for b in range(pg.n_blocks):
                    real = spb[i, k, b][spb[i, k, b] >= 0]
                    if real.size:
                        assert lo[i, k, b] == real.min()
                        assert hi[i, k, b] == real.max()
                    else:
                        assert hi[i, k, b] == -1

    @pytest.mark.parametrize("n,blk", [(1, 32), (3, 16), (4, 64), (8, 8)])
    def test_rmat(self, n, blk):
        self._check(rmat_graph(scale=6, edge_factor=6, seed=2), n, blk)

    def test_sparse_id_graph(self):
        self._check(rmat_graph(scale=6, edge_factor=4, seed=5,
                               sparse_ids=True), 4, 32)

    def test_undirected_symmetry(self):
        g = erdos_renyi_graph(150, 3.0, seed=4, directed=False)
        pairs = set(zip(g.src.tolist(), g.dst.tolist()))
        assert all((d, s) in pairs for s, d in pairs)

    def test_star_hub_degree(self):
        g = star_graph(100)
        pg, rmap = partition_graph(g, 4, edge_block=16)
        deg = np.asarray(pg.degree)
        hub_new = int(rmap.to_new(np.array([0]))[0])
        assert deg[hub_new % 4, hub_new // 4] == 99

    def test_chain_structure(self):
        g = chain_graph(64)
        pg, _ = partition_graph(g, 4, edge_block=8)
        assert np.asarray(pg.degree).sum() == 63


class TestKernelLayout:
    def test_layout_preserves_edges_and_invariants(self):
        from repro.graph.kblocks import build_kernel_layout

        g = rmat_graph(scale=7, edge_factor=8, seed=3)
        pg, _ = partition_graph(g, n_shards=4, edge_block=64, vertex_pad=32)
        kl = build_kernel_layout(pg, BLK=32, SRC_WIN=32, DST_WIN=32)
        n = 4
        sp0, dp0 = np.asarray(pg.src_pos), np.asarray(pg.dst_pos)
        spk, dpk = np.asarray(kl.sp), np.asarray(kl.dp)
        swin = np.asarray(kl.blk_swin)
        dwin = np.asarray(kl.blk_dwin)
        for i in range(n):
            for k in range(n):
                a = collections.Counter(
                    zip(sp0[i, k][sp0[i, k] >= 0].tolist(),
                        dp0[i, k][sp0[i, k] >= 0].tolist())
                )
                m = spk[i, k] >= 0
                b = collections.Counter(
                    zip(spk[i, k][m].tolist(), dpk[i, k][m].tolist())
                )
                assert a == b  # edge-conservation across re-tiling
                for blk in range(kl.NB):
                    real_s = spk[i, k, blk][spk[i, k, blk] >= 0]
                    real_d = dpk[i, k, blk][spk[i, k, blk] >= 0]
                    if real_s.size:
                        # every block's srcs fit its aligned SRC_WIN window
                        assert (real_s // kl.SRC_WIN == swin[i, k, blk]).all()
                        # and dsts fit its DST_WIN window
                        assert (real_d // kl.DST_WIN == dwin[i, k, blk]).all()
                # every dst window initialized by some block
                assert set(range(pg.P // kl.DST_WIN)) <= set(
                    dwin[i, k].tolist()
                )
