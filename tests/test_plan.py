"""Resource-aware planner: mode choice flips as the budget shrinks, knobs are
derived from the budget (not compiled-in), infeasible budgets fail with the
byte breakdown, plans are explainable and JSON round-trippable, and the
predictive algebra IS the engine's realized memory_model."""

import json

import numpy as np
import pytest

from repro.core import (
    DistinctInLabels, ExecutionPlan, GraphDEngine, GraphMeta, HashMin,
    MemoryBudget, PageRank, PlanInfeasible, estimate_memory, plan,
)
from repro.core.plan import ram_total
from repro.graph import partition_graph, partition_graph_streamed, rmat_graph

N = 3
EDGE_BLOCK = 32


@pytest.fixture(scope="module")
def graph():
    return rmat_graph(scale=10, edge_factor=8, seed=5)


def _floors(graph, *, combined=True, value_itemsize=4, msg_itemsize=4):
    """RAM floors of the streamed candidates, computed with the same algebra
    the planner runs (geometry estimated exactly as plan() estimates it)."""
    P = -(-graph.n_vertices // N)
    P = max((P + 7) // 8 * 8, 8)
    E_cap = max(int(graph.n_edges / (N * N) * 1.5 + EDGE_BLOCK - 1)
                // EDGE_BLOCK * EDGE_BLOCK, EDGE_BLOCK)
    common = dict(n_shards=N, P=P, E_cap=E_cap, edge_block=EDGE_BLOCK,
                  value_itemsize=value_itemsize, msg_itemsize=msg_itemsize,
                  combined=combined, chunk_blocks=1, slice_cap=128,
                  read_chunk=64, merge_fanin=2, inflight=1, group_batch=1)
    streamed = ram_total(
        estimate_memory(mode="streamed", pipeline=False, **common),
        "streamed")
    # the ladder floor: batch lanes and the full-duplex receiver staging
    # are shed before the pipeline is declared infeasible
    pipelined = ram_total(
        estimate_memory(mode="streamed", pipeline=True, full_duplex=False,
                        **common),
        "streamed")
    return streamed, pipelined


def test_shrinking_budget_flips_recoded_to_streamed_to_pipelined(graph):
    """The tentpole table: same program, same graph — only ram_per_shard
    moves, and the chosen mode walks recoded -> streamed ->
    streamed+pipeline (the pipelined fold keeps ONE accumulator instead of
    n, so it fits where the plain streamed fold no longer does)."""
    prog = PageRank(supersteps=3)
    floor_streamed, floor_pipe = _floors(graph, combined=True)
    assert floor_pipe < floor_streamed  # the flip window exists

    cases = [
        (None, "recoded", False),
        (floor_streamed, "streamed", False),
        (floor_pipe, "streamed", True),
    ]
    for ram, want_mode, want_pipeline in cases:
        p = plan(prog, graph, MemoryBudget(ram_per_shard=ram, n_shards=N),
                 edge_block=EDGE_BLOCK)
        assert p.mode == want_mode, (ram, p.explain())
        assert p.pipeline == want_pipeline, (ram, p.explain())
        if ram is not None:
            assert p.ram_total <= ram


def test_combinerless_flips_basic_to_streamed(graph):
    """Combiner-less programs flip basic -> streamed(OMS). There is no
    pipelined third step here: a raw-message channel only ADDS in-flight
    packet RAM (unlike the combiner path, where pipelining collapses the n
    destination accumulators to one), so the planner must never pick it as
    the budget-saver."""
    prog = DistinctInLabels(n_groups=8)
    floor_streamed, floor_pipe = _floors(graph, combined=False)
    assert floor_pipe > floor_streamed  # pipeline cannot save RAM here
    p_loose = plan(prog, graph, MemoryBudget(n_shards=N),
                   edge_block=EDGE_BLOCK)
    assert p_loose.mode == "basic"  # in-memory merge-sort when RAM allows
    p_tight = plan(prog, graph,
                   MemoryBudget(ram_per_shard=floor_streamed, n_shards=N),
                   edge_block=EDGE_BLOCK)
    assert p_tight.mode == "streamed" and not p_tight.pipeline
    with pytest.raises(PlanInfeasible):
        plan(prog, graph,
             MemoryBudget(ram_per_shard=floor_streamed // 4, n_shards=N),
             edge_block=EDGE_BLOCK)


def test_planner_sizes_oms_windows_from_budget(graph):
    """The PR-2 ceiling fix: 559 KB of the measured combiner-less RAM was
    the compiled-in merge/slice windows. Under a tight budget the planner
    must shrink msg_read_chunk/msg_slice_cap/msg_merge_fanin instead of
    giving up — and the resulting msg_staging must fit the budget."""
    prog = DistinctInLabels(n_groups=8)
    floor_streamed, _ = _floors(graph, combined=False)
    defaults = plan(prog, graph, MemoryBudget(n_shards=N),
                    edge_block=EDGE_BLOCK)
    tight = plan(prog, graph,
                 MemoryBudget(ram_per_shard=floor_streamed + 16 * 1024,
                              n_shards=N),
                 edge_block=EDGE_BLOCK)
    assert tight.mode == "streamed"
    d, t = defaults.config.spill, tight.config.spill
    assert (t.read_chunk, t.slice_cap) < (d.read_chunk, d.slice_cap)
    assert tight.model["msg_staging"] < 559 * 1024
    assert tight.ram_total <= floor_streamed + 16 * 1024


def test_overconstrained_budget_raises_with_byte_breakdown(graph):
    with pytest.raises(PlanInfeasible) as ei:
        plan(PageRank(supersteps=3), graph,
             MemoryBudget(ram_per_shard=256, n_shards=N),
             edge_block=EDGE_BLOCK)
    msg = str(ei.value)
    # the breakdown is in the MESSAGE (actionable from a log line alone)
    for tier in ("resident=", "buffers=", "staging=", "channel="):
        assert tier in msg
    assert "most frugal" in msg
    bd = ei.value.breakdown
    assert bd["budget"]["ram_per_shard"] == 256
    assert {c["name"] for c in bd["candidates"]} >= {
        "recoded", "streamed", "streamed+pipeline"}
    assert all(not c["feasible"] for c in bd["candidates"])


def test_explain_output_for_two_budgets(graph):
    """The acceptance check: plan.explain() prints the per-tier byte model
    and why each alternative was rejected, for at least two budgets."""
    prog = PageRank(supersteps=3)
    loose = plan(prog, graph, MemoryBudget(n_shards=N),
                 edge_block=EDGE_BLOCK).explain()
    assert "ExecutionPlan: recoded" in loose
    assert "model/shard: resident=" in loose
    assert "budget: ram/shard=unbounded" in loose
    assert "recoded              CHOSEN" in loose
    # dominated alternative carries its reason
    assert "dominated by recoded" in loose

    floor_streamed, floor_pipe = _floors(graph, combined=True)
    tight = plan(prog, graph,
                 MemoryBudget(ram_per_shard=floor_pipe, n_shards=N),
                 edge_block=EDGE_BLOCK).explain()
    assert "ExecutionPlan: streamed+pipeline" in tight
    assert "streamed+pipeline    CHOSEN" in tight
    # both in-memory and plain-streamed rejections name the blown tier
    assert "recoded              REJECTED" in tight
    assert "edge groups resident" in tight
    assert "streamed             REJECTED" in tight
    assert "even at floor knobs" in tight
    assert "knobs:" in tight


def test_disk_budget_engages_compression(graph):
    prog = PageRank(supersteps=3)
    floor_streamed, _ = _floors(graph, combined=True)
    base = plan(prog, graph,
                MemoryBudget(ram_per_shard=floor_streamed, n_shards=N),
                edge_block=EDGE_BLOCK)
    assert not base.compress
    squeezed = plan(
        prog, graph,
        MemoryBudget(ram_per_shard=floor_streamed, n_shards=N,
                     disk_per_shard=int(base.disk_total * 0.8)),
        edge_block=EDGE_BLOCK)
    assert squeezed.compress
    assert squeezed.disk_total < base.disk_total
    assert "+compress" in squeezed.explain()


def test_net_budget_prefers_compact_wire(graph):
    prog = PageRank(supersteps=3)
    loose = plan(prog, graph, MemoryBudget(n_shards=N))
    rec = next(c for c in loose.alternatives if c.name == "recoded")
    squeezed = plan(prog, graph,
                    MemoryBudget(n_shards=N,
                                 net_per_superstep=rec.net_total - 1))
    assert squeezed.mode == "recoded_compact"


def test_net_budget_binds_streamed_candidates_too(graph):
    """A net budget nobody can meet must raise PlanInfeasible — the
    streamed candidates' transmissions model cross-machine traffic in
    deployment, so they may not silently bypass the constraint."""
    with pytest.raises(PlanInfeasible) as ei:
        plan(PageRank(supersteps=3), graph,
             MemoryBudget(n_shards=N, net_per_superstep=100))
    cands = ei.value.breakdown["candidates"]
    for c in cands:
        assert not c["feasible"]
    assert any("net" in c["reason"] for c in cands
               if c["name"].startswith("streamed"))


def test_plan_json_round_trip(graph):
    floor_streamed, floor_pipe = _floors(graph, combined=True)
    p = plan(PageRank(supersteps=3), graph,
             MemoryBudget(ram_per_shard=floor_pipe, n_shards=N),
             edge_block=EDGE_BLOCK)
    s = p.to_json()
    json.loads(s)  # valid JSON
    assert ExecutionPlan.from_json(s) == p


def test_realized_memory_model_matches_plan(graph, tmp_path):
    """Planned and realized models are ONE algebra: planning against the
    realized partition geometry, the engine's memory_model() agrees tier
    for tier (the disk tier is measured, so it is compared within 2x)."""
    prog = PageRank(supersteps=2)
    pgs, _, store = partition_graph_streamed(
        graph, N, str(tmp_path / "s"), edge_block=EDGE_BLOCK,
    )
    # a budget sized to the default-knob streamed model of THIS partition:
    # in-memory recoded (edge groups resident) cannot fit, streamed just does
    ram = ram_total(
        estimate_memory(mode="streamed", n_shards=N, P=pgs.P,
                        E_cap=pgs.E_cap, edge_block=EDGE_BLOCK,
                        value_itemsize=4, msg_itemsize=4, combined=True),
        "streamed")
    p = plan(prog, GraphMeta.of(pgs),
             MemoryBudget(ram_per_shard=ram, n_shards=N),
             edge_block=EDGE_BLOCK)
    assert p.mode == "streamed"
    eng = GraphDEngine(pgs, prog, config=p.config, stream_store=store)
    realized = eng.memory_model()
    for tier, planned in p.model.items():
        if tier == "streamed":  # estimated from E/n^2 * skew vs real layout
            assert planned <= 2 * realized[tier]
            assert realized[tier] <= 2 * planned
        else:
            assert realized[tier] == planned, tier
    # RAM totals (which exclude the disk tier) agree exactly
    assert ram_total(realized, "streamed") == p.ram_total


def test_graph_meta_of_accepts_graph_and_partition(graph):
    m1 = GraphMeta.of(graph)
    pg, _ = partition_graph(graph, n_shards=N, edge_block=EDGE_BLOCK)
    m2 = GraphMeta.of(pg)
    assert (m1.n_vertices, m1.n_edges) == (m2.n_vertices, m2.n_edges)
    assert m1.n_vertices == graph.n_vertices
    assert m1.max_shard_vertices is None  # a raw Graph has no realized P
    assert (m2.max_shard_vertices, m2.for_n_shards) == (pg.P, N)
    assert GraphMeta.of(m1) is m1


def test_net_budget_flips_payload_compression(graph):
    """Satellite: a shrinking net_per_superstep budget must engage the
    position codec, then compress_payload, BEFORE declaring PlanInfeasible
    — the wire codecs are the planner's net-budget ladder."""
    prog = PageRank(supersteps=3)
    floor_streamed, _ = _floors(graph, combined=True)
    ram = floor_streamed + 8192  # forces streamed; slack for codec scratch
    base = plan(prog, graph, MemoryBudget(ram_per_shard=ram, n_shards=N),
                edge_block=EDGE_BLOCK)
    assert base.mode == "streamed"
    assert not base.compress and not base.compress_payload

    step1 = plan(prog, graph,
                 MemoryBudget(ram_per_shard=ram, n_shards=N,
                              net_per_superstep=base.net_total - 1),
                 edge_block=EDGE_BLOCK)
    assert step1.mode == "streamed" and step1.compress
    assert not step1.compress_payload  # positions alone satisfied this one
    assert step1.net_total < base.net_total

    step2 = plan(prog, graph,
                 MemoryBudget(ram_per_shard=ram, n_shards=N,
                              net_per_superstep=step1.net_total - 1),
                 edge_block=EDGE_BLOCK)
    assert step2.mode == "streamed"
    assert step2.compress and step2.compress_payload
    assert step2.net_total < step1.net_total
    assert "+payload" in step2.explain()
    assert "codec" in step2.model  # the payload-codec scratch tier rides

    with pytest.raises(PlanInfeasible) as ei:
        plan(prog, graph,
             MemoryBudget(ram_per_shard=ram, n_shards=N,
                          net_per_superstep=step2.net_total - 1),
             edge_block=EDGE_BLOCK)
    cands = ei.value.breakdown["candidates"]
    streamed_cands = [c for c in cands if c["mode"] == "streamed"]
    assert streamed_cands and all(
        c["compress"] and c["compress_payload"] for c in streamed_cands
    )  # both codecs were engaged before giving up
    assert any("payload codec" in c["reason"] for c in streamed_cands)


def test_measured_link_throughput_prices_candidates(graph):
    """Satellite: ``estimate_net`` grew a measured companion — a probe of
    the real socket frame path prices every candidate's per-superstep NIC
    bytes in seconds (``Candidate.net_seconds``), explain() prints it, and
    the figure survives the JSON round trip."""
    from repro.core.plan import (
        ExecutionPlan, estimate_net_seconds, measured_link_throughput,
    )

    assert estimate_net_seconds(10 << 20, 10 << 20) == 1.0
    with pytest.raises(ValueError, match="positive"):
        estimate_net_seconds(1, 0.0)

    bw = measured_link_throughput(n_bytes=1 << 20)
    assert bw > 0  # loopback TCP through the frame path really moved bytes

    p = plan(HashMin(), graph, MemoryBudget(n_shards=N),
             edge_block=EDGE_BLOCK, launch="processes", link_bytes_per_s=bw)
    chosen = next(c for c in p.alternatives if c.chosen)
    assert chosen.net_seconds == pytest.approx(chosen.net_total / bw)
    assert "at measured link" in p.explain()
    p2 = ExecutionPlan.from_json(p.to_json())
    assert [c.net_seconds for c in p2.alternatives] == \
           [c.net_seconds for c in p.alternatives]

    # without a probe the field stays 0.0 and explain() omits the pricing
    p0 = plan(HashMin(), graph, MemoryBudget(n_shards=N),
              edge_block=EDGE_BLOCK)
    assert all(c.net_seconds == 0.0 for c in p0.alternatives)
    assert "at measured link" not in p0.explain()


def test_receiver_staging_tier_in_explain_and_breakdown(graph):
    """Satellite: the full-duplex receiver's RAM tier is part of the model,
    printed by plan.explain(), and carried in the JSON byte breakdown."""
    prog = PageRank(supersteps=3)
    n = 8  # enough shards that the pipelined fold beats n+1 accumulators
    P = max((-(-graph.n_vertices // n) + 7) // 8 * 8, 8)
    E_cap = max(int(graph.n_edges / (n * n) * 1.5 + EDGE_BLOCK - 1)
                // EDGE_BLOCK * EDGE_BLOCK, EDGE_BLOCK)
    common = dict(n_shards=n, P=P, E_cap=E_cap, edge_block=EDGE_BLOCK,
                  value_itemsize=4, msg_itemsize=4, combined=True,
                  chunk_blocks=1, inflight=1, group_batch=1)
    pipe_fd = ram_total(
        estimate_memory(mode="streamed", pipeline=True, full_duplex=True,
                        **common), "streamed")
    plain_floor = ram_total(
        estimate_memory(mode="streamed", pipeline=False, **common),
        "streamed")
    assert pipe_fd < plain_floor  # full duplex fits where plain cannot

    p = plan(prog, graph, MemoryBudget(ram_per_shard=pipe_fd, n_shards=n),
             edge_block=EDGE_BLOCK)
    assert p.mode == "streamed" and p.pipeline
    assert p.config.channel.full_duplex
    assert "receiver_staging" in p.model and p.model["receiver_staging"] > 0
    assert "receiver_staging=" in p.explain()
    chosen = next(json.loads(p.to_json())["alternatives"][i]
                  for i, c in enumerate(p.alternatives) if c.chosen)
    assert "receiver_staging" in chosen["model"]
